//! The FDB: a domain-specific object store for meteorological data
//! (thesis Chapters 2–3).
//!
//! The architecture is trait-based: [`backend::Store`] (field data) and
//! [`backend::Catalogue`] (the index network) are object-safe traits
//! implemented by each backend pair — POSIX/Lustre, DAOS, Ceph/RADOS,
//! S3 (+ the in-memory Null pair). [`Fdb`] holds one boxed trait object
//! of each and dispatches every operation virtually, with trace and
//! distributed-lock accounting in one shared wrapper. The [`wrappers`]
//! module exploits that: [`wrappers::TieredStore`],
//! [`wrappers::ReplicatedStore`] and [`wrappers::ShardedCatalogue`]
//! wrap *other* backends and compose recursively through
//! [`BackendConfig`] (a tiered store over a replicated store with a
//! sharded catalogue is one config tree).
//!
//! Construction is declarative: a [`BackendConfig`] names the pair and
//! its knobs (`Daos { pool, hash_oids }`, `Rados { store, .. }`,
//! `Tiered { front, back }`, ...) and [`FdbBuilder`] validates it and
//! wires the matching pair. Backend failures are typed
//! ([`FdbError::Backend`], [`FdbError::AllReplicasFailed`]) — archive/
//! flush paths (store *and* catalogue side) return `Result` instead of
//! panicking inside the simulator. On top of the one-field calls,
//! [`Fdb::archive_many`] and [`Fdb::retrieve_many`] provide the batched
//! paths — catalogue lookups pipelined with store reads — that the DAOS
//! interface papers (arXiv:2311.18714, arXiv:2409.18682) identify as
//! the key to scalable small-object I/O.
//!
//! The batched paths scale past one outstanding op through the
//! **I/O-depth engine**: an [`IoProfile`] (`FdbBuilder::io` /
//! `io_depth`, `fdbctl hammer --io-depth N`) mints per-request client
//! sessions ([`backend::StoreSession`], one forked backend client each)
//! and a sim-native semaphore admits up to `depth` concurrent store
//! reads/writes, with results re-ordered to input order. Depth 1 is
//! bit-for-bit the legacy serial behaviour; any depth returns identical
//! bytes — only virtual time changes (see the `abl_iodepth` ablation).
//!
//! Orthogonal to queue depth, the **vectored read planner** ([`plan`])
//! attacks the op count itself: with [`IoProfile::coalesce_gap`] > 0,
//! `retrieve_many` groups catalogue-resolved locations by physical
//! container, merges adjacent fields into large ranged I/Os (issued via
//! [`Store::read_ranges`](backend::Store::read_ranges)), and slices the
//! merged buffers back per field — fewer, bigger ops on the same bytes
//! (the `abl_coalesce` ablation records the win).

pub mod admin;
pub mod backend;
pub mod builder;
pub mod datahandle;
pub(crate) mod engine;
pub mod fault;
pub mod fdb;
pub mod key;
pub mod location;
pub mod plan;
pub mod request;
pub mod schema;
pub mod scrub;
pub mod telemetry;
pub mod wire;

pub mod posix {
    pub mod catalogue;
    pub mod index;
    pub mod store;
    pub mod toc;
}

pub mod daos {
    pub mod catalogue;
    pub mod store;
}

pub mod rados {
    pub mod catalogue;
    pub mod store;
}

pub mod s3 {
    pub mod store;
}

pub mod wrappers;

pub use backend::{
    Catalogue, CatalogueSession, NullCatalogue, NullStore, SharedNullCatalogue, Store,
    StoreSession,
};
pub use builder::{BackendConfig, FdbBuilder, IoProfile, ResilienceProfile};
pub use fault::{FaultCatalogue, FaultPlan, FaultStore, RecoveryStats};
pub use datahandle::DataHandle;
pub use fdb::Fdb;
pub use key::Key;
pub use location::FieldLocation;
pub use plan::{PlanStats, ReadPlan};
pub use request::Request;
pub use schema::Schema;
pub use scrub::FsckReport;
pub use telemetry::{is_transient, HistogramSnapshot, MetricsRegistry, SlowOp};

/// FDB error surface.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum FdbError {
    Schema(schema::SchemaError),
    UnderspecifiedRequest,
    /// A [`DataHandle`] minted by one Store was read through another.
    BackendMismatch {
        store: &'static str,
        handle: &'static str,
    },
    /// A [`BackendConfig`] failed [`FdbBuilder`] validation.
    InvalidConfig(String),
    /// A backend operation failed (filesystem error, stale multipart
    /// upload state, ...). Replaces the former backend-internal panics.
    Backend {
        backend: &'static str,
        detail: String,
    },
    /// Every replica of a [`wrappers::ReplicatedStore`] failed the
    /// operation; `last` is an underlying replica error, preferring a
    /// transient one when the failures were mixed — so the engine's
    /// retry policy (which classifies this error by recursing into
    /// `last`) keeps retrying while any replica is worth re-probing.
    AllReplicasFailed {
        op: &'static str,
        copies: usize,
        last: Box<FdbError>,
    },
    /// A backend operation outran its per-op deadline
    /// ([`ResilienceProfile::op_deadline_us`]) and was abandoned by the
    /// I/O engine. Always retryable.
    Timeout {
        class: &'static str,
        micros: u64,
    },
    /// Integrity violation: stored bytes no longer match what was
    /// archived (checksum mismatch, torn/bit-flipped index blob, ...).
    /// Never transient — retrying the same read returns the same rotten
    /// bytes; recovery is repair-from-replica or `fdbctl fsck --repair`.
    Corrupt {
        what: &'static str,
        detail: String,
    },
}

impl From<schema::SchemaError> for FdbError {
    fn from(e: schema::SchemaError) -> FdbError {
        FdbError::Schema(e)
    }
}

impl std::fmt::Display for FdbError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            FdbError::Schema(e) => write!(f, "schema: {e}"),
            FdbError::UnderspecifiedRequest => {
                write!(f, "request lacks dataset/collocation dims for axis expansion")
            }
            FdbError::BackendMismatch { store, handle } => write!(
                f,
                "DataHandle backend mismatch: `{handle}` handle read through the `{store}` store"
            ),
            FdbError::InvalidConfig(msg) => write!(f, "invalid backend config: {msg}"),
            FdbError::Backend { backend, detail } => {
                write!(f, "{backend} backend error: {detail}")
            }
            FdbError::AllReplicasFailed { op, copies, last } => write!(
                f,
                "all {copies} replicas failed {op}; last error: {last}"
            ),
            FdbError::Timeout { class, micros } => {
                write!(f, "{class} op exceeded its {micros} us deadline")
            }
            FdbError::Corrupt { what, detail } => {
                write!(f, "corrupt {what}: {detail}")
            }
        }
    }
}
impl std::error::Error for FdbError {}

#[cfg(test)]
mod tests {
    use std::rc::Rc;

    use super::*;
    use crate::ceph::{Ceph, CephConfig, Redundancy};
    use crate::daos::{Daos, DaosConfig};
    use crate::hw::profiles::{build_cluster, Testbed};
    use crate::lustre::{Lustre, LustreConfig};
    use crate::sim::exec::Sim;

    fn ids(n_steps: u32, n_params: u32) -> Vec<Key> {
        let mut out = Vec::new();
        for step in 1..=n_steps {
            for p in 0..n_params {
                out.push(
                    schema::example_identifier()
                        .with("step", step.to_string())
                        .with("param", format!("p{p}")),
                );
            }
        }
        out
    }

    fn field_bytes(id: &Key) -> Vec<u8> {
        format!("FIELD::{}", id.canonical()).into_bytes()
    }

    async fn writer_reader_roundtrip(mut w: Fdb, mut r: Fdb) {
        let ids = ids(3, 4);
        for id in &ids {
            w.archive(id, field_bytes(id)).await.unwrap();
        }
        w.flush().await.expect("flush");
        w.close().await.expect("close");
        // reader sees every field with exact bytes
        for id in &ids {
            let h = r
                .retrieve(id)
                .await
                .unwrap()
                .unwrap_or_else(|| panic!("missing {id}"));
            let bytes = r.read(&h).await.unwrap().to_vec();
            assert_eq!(bytes, field_bytes(id), "bytes for {id}");
        }
        // absent field: no error, no handle
        let missing = schema::example_identifier().with("step", "999");
        assert!(r.retrieve(&missing).await.unwrap().is_none());
        // list the whole dataset
        let ds = schema::example_identifier()
            .project(&r.schema.dataset.clone())
            .unwrap();
        let listed = r.list(&ds, &Request::parse("").unwrap()).await;
        assert_eq!(listed.len(), ids.len());
    }

    fn posix_config(fs: &Rc<Lustre>) -> BackendConfig {
        BackendConfig::Posix {
            fs: fs.clone(),
            root: "/fdb".to_string(),
        }
    }

    fn daos_config(daos: &Rc<Daos>) -> BackendConfig {
        BackendConfig::Daos {
            daos: daos.clone(),
            pool: "fdb".to_string(),
            hash_oids: false,
        }
    }

    #[test]
    fn posix_end_to_end() {
        let sim = Sim::new();
        let cluster = Rc::new(build_cluster(Testbed::NextGenIo, 2, 2, true, true));
        let fs = Lustre::deploy(&sim, &cluster, LustreConfig::default());
        let wnode = cluster.client_nodes().next().unwrap().clone();
        let rnode = cluster.client_nodes().nth(1).unwrap().clone();
        let w = FdbBuilder::new(&sim)
            .node(&wnode)
            .backend(posix_config(&fs))
            .build()
            .unwrap();
        let r = FdbBuilder::new(&sim)
            .node(&rnode)
            .backend(posix_config(&fs))
            .build()
            .unwrap();
        sim.spawn(async move { writer_reader_roundtrip(w, r).await });
        sim.run();
    }

    #[test]
    fn daos_end_to_end() {
        let sim = Sim::new();
        let cluster = Rc::new(build_cluster(Testbed::NextGenIo, 2, 2, false, false));
        let daos = Daos::deploy(&sim, &cluster, DaosConfig::default());
        daos.create_pool("fdb");
        let wnode = cluster.client_nodes().next().unwrap().clone();
        let rnode = cluster.client_nodes().nth(1).unwrap().clone();
        let w = FdbBuilder::new(&sim)
            .node(&wnode)
            .backend(daos_config(&daos))
            .build()
            .unwrap();
        let r = FdbBuilder::new(&sim)
            .node(&rnode)
            .backend(daos_config(&daos))
            .build()
            .unwrap();
        sim.spawn(async move { writer_reader_roundtrip(w, r).await });
        sim.run();
    }

    #[test]
    fn rados_end_to_end() {
        let sim = Sim::new();
        let cluster = Rc::new(build_cluster(Testbed::Gcp, 4, 2, true, true));
        let ceph = Ceph::deploy(&sim, &cluster, CephConfig::default());
        let pool = ceph.create_pool("fdb", 512, Redundancy::None);
        let wnode = cluster.client_nodes().next().unwrap().clone();
        let rnode = cluster.client_nodes().nth(1).unwrap().clone();
        let mk = |node: &Rc<crate::hw::node::Node>| {
            FdbBuilder::new(&sim)
                .node(node)
                .backend(BackendConfig::Rados {
                    ceph: ceph.clone(),
                    pool: pool.clone(),
                    store: crate::fdb::rados::store::RadosStoreConfig::default(),
                })
                .build()
                .unwrap()
        };
        let w = mk(&wnode);
        let r = mk(&rnode);
        sim.spawn(async move { writer_reader_roundtrip(w, r).await });
        sim.run();
    }

    #[test]
    fn s3_store_roundtrip_same_process() {
        // No S3 catalogue: the Null catalogue is process-local, so the
        // writer retrieves its own fields (the thesis verified the S3
        // Store with local deployments the same way).
        let sim = Sim::new();
        let cluster = Rc::new(build_cluster(Testbed::Gcp, 1, 1, false, true));
        let server = cluster.storage_nodes().next().unwrap().clone();
        let cnode = cluster.client_nodes().next().unwrap().clone();
        let s3 = Rc::new(crate::s3::MemS3::new(&sim, &server, &cnode));
        let mut w = FdbBuilder::new(&sim)
            .backend(BackendConfig::S3 {
                s3: s3.clone(),
                client_tag: "p0".to_string(),
                multipart: false,
            })
            .build()
            .unwrap();
        sim.spawn(async move {
            let ids = ids(2, 3);
            for id in &ids {
                w.archive(id, field_bytes(id)).await.unwrap();
            }
            w.flush().await.expect("flush");
            for id in &ids {
                let h = w.retrieve(id).await.unwrap().unwrap();
                assert_eq!(w.read(&h).await.unwrap().to_vec(), field_bytes(id));
            }
        });
        sim.run();
    }

    #[test]
    fn posix_visibility_requires_flush() {
        // ACID semantics item 3: data visible only after flush() on POSIX
        let sim = Sim::new();
        let cluster = Rc::new(build_cluster(Testbed::NextGenIo, 2, 2, true, true));
        let fs = Lustre::deploy(&sim, &cluster, LustreConfig::default());
        let wnode = cluster.client_nodes().next().unwrap().clone();
        let rnode = cluster.client_nodes().nth(1).unwrap().clone();
        let mut w = FdbBuilder::new(&sim)
            .node(&wnode)
            .backend(posix_config(&fs))
            .build()
            .unwrap();
        let fs2 = fs.clone();
        let sim2 = sim.clone();
        sim.spawn(async move {
            let id = schema::example_identifier();
            w.archive(&id, b"payload").await.unwrap();
            // reader BEFORE flush: index not yet persisted
            let mut r1 = FdbBuilder::new(&sim2)
                .node(&rnode)
                .backend(posix_config(&fs2))
                .build()
                .unwrap();
            assert!(r1.retrieve(&id).await.unwrap().is_none());
            w.flush().await.expect("flush");
            // fresh reader AFTER flush: visible
            let mut r2 = FdbBuilder::new(&sim2)
                .node(&rnode)
                .backend(posix_config(&fs2))
                .build()
                .unwrap();
            assert!(r2.retrieve(&id).await.unwrap().is_some());
        });
        sim.run();
    }

    #[test]
    fn daos_visible_immediately_without_flush() {
        let sim = Sim::new();
        let cluster = Rc::new(build_cluster(Testbed::NextGenIo, 2, 2, false, false));
        let daos = Daos::deploy(&sim, &cluster, DaosConfig::default());
        daos.create_pool("fdb");
        let wnode = cluster.client_nodes().next().unwrap().clone();
        let rnode = cluster.client_nodes().nth(1).unwrap().clone();
        let mut w = FdbBuilder::new(&sim)
            .node(&wnode)
            .backend(daos_config(&daos))
            .build()
            .unwrap();
        let mut r = FdbBuilder::new(&sim)
            .node(&rnode)
            .backend(daos_config(&daos))
            .build()
            .unwrap();
        sim.spawn(async move {
            let id = schema::example_identifier();
            w.archive(&id, b"now").await.unwrap();
            // NO flush — still visible (thesis §3.1 immediate persistence)
            let h = r.retrieve(&id).await.unwrap().unwrap();
            assert_eq!(r.read(&h).await.unwrap().to_vec(), b"now");
        });
        sim.run();
    }

    #[test]
    fn rearchive_replaces_transactionally() {
        let sim = Sim::new();
        let cluster = Rc::new(build_cluster(Testbed::NextGenIo, 2, 2, false, false));
        let daos = Daos::deploy(&sim, &cluster, DaosConfig::default());
        daos.create_pool("fdb");
        let node = cluster.client_nodes().next().unwrap().clone();
        let mut w = FdbBuilder::new(&sim)
            .node(&node)
            .backend(daos_config(&daos))
            .build()
            .unwrap();
        let rnode = cluster.client_nodes().nth(1).unwrap().clone();
        let mut r = FdbBuilder::new(&sim)
            .node(&rnode)
            .backend(daos_config(&daos))
            .build()
            .unwrap();
        sim.spawn(async move {
            let id = schema::example_identifier();
            w.archive(&id, b"old-data").await.unwrap();
            w.archive(&id, b"new-data").await.unwrap();
            let h = r.retrieve(&id).await.unwrap().unwrap();
            assert_eq!(r.read(&h).await.unwrap().to_vec(), b"new-data");
        });
        sim.run();
    }

    #[test]
    fn wildcard_request_expands_from_axes() {
        let sim = Sim::new();
        let cluster = Rc::new(build_cluster(Testbed::NextGenIo, 2, 2, false, false));
        let daos = Daos::deploy(&sim, &cluster, DaosConfig::default());
        daos.create_pool("fdb");
        let node = cluster.client_nodes().next().unwrap().clone();
        let mut w = FdbBuilder::new(&sim)
            .node(&node)
            .backend(daos_config(&daos))
            .build()
            .unwrap();
        let rnode = cluster.client_nodes().nth(1).unwrap().clone();
        let mut r = FdbBuilder::new(&sim)
            .node(&rnode)
            .backend(daos_config(&daos))
            .build()
            .unwrap();
        sim.spawn(async move {
            for step in 1..=5u32 {
                let id = schema::example_identifier().with("step", step.to_string());
                w.archive(&id, format!("s{step}").as_bytes()).await.unwrap();
            }
            // request step=* for the same (ds, colloc, param)
            let base = schema::example_identifier();
            let mut req = Request::from_key(&base);
            req.bind("step", vec![]); // wildcard
            let handles = r.retrieve_request(&req).await.unwrap();
            let total: u64 = handles.iter().map(|h| h.total_len()).sum();
            assert_eq!(total, 10); // "s1".."s5" → 2 bytes each
            // the streaming path delivers the same fields with bytes
            let fetched = r.retrieve_request_streaming(&req).await.unwrap();
            assert_eq!(fetched.len(), 5);
            let streamed: u64 = fetched.iter().map(|(_, b)| b.len()).sum();
            assert_eq!(streamed, 10);
        });
        sim.run();
    }

    #[test]
    fn posix_datahandle_merging_reduces_io_ops() {
        let sim = Sim::new();
        let cluster = Rc::new(build_cluster(Testbed::NextGenIo, 2, 2, true, true));
        let fs = Lustre::deploy(&sim, &cluster, LustreConfig::default());
        let wnode = cluster.client_nodes().next().unwrap().clone();
        let rnode = cluster.client_nodes().nth(1).unwrap().clone();
        let mut w = FdbBuilder::new(&sim)
            .node(&wnode)
            .backend(posix_config(&fs))
            .build()
            .unwrap();
        let sim2 = sim.clone();
        let fs2 = fs.clone();
        sim.spawn(async move {
            let mut ids = Vec::new();
            for step in 1..=6u32 {
                let id = schema::example_identifier().with("step", step.to_string());
                w.archive(&id, vec![step as u8; 128]).await.unwrap();
                ids.push(id);
            }
            w.flush().await.expect("flush");
            w.close().await.expect("close");
            let mut r = FdbBuilder::new(&sim2)
                .node(&rnode)
                .backend(posix_config(&fs2))
                .build()
                .unwrap();
            let mut req = Request::from_key(&ids[0]);
            req.bind("step", (1..=6).map(|s| s.to_string()).collect());
            let handles = r.retrieve_request(&req).await.unwrap();
            // all 6 fields were appended to one data file consecutively →
            // one handle, one coalesced range
            assert_eq!(handles.len(), 1);
            assert_eq!(handles[0].io_ops(), 1);
            assert_eq!(handles[0].total_len(), 6 * 128);
            let bytes = r.read(&handles[0]).await.unwrap();
            assert_eq!(bytes.len(), 6 * 128);
        });
        sim.run();
    }
}
