//! Bounded op-level event journal: a ring buffer of completed spans,
//! exported as Chrome trace-event JSON (`chrome://tracing` / Perfetto).
//!
//! The journal is overhead-bounded by construction: a fixed-capacity
//! `VecDeque` where overflow drops the OLDEST span (the most recent
//! window of activity is what a trace viewer wants) and counts the
//! drops, so a long hammer run can keep the journal attached without
//! growing without bound.

use std::collections::VecDeque;

use crate::sim::time::SimTime;
use crate::util::json::Json;

/// One completed operation span on a track.
#[derive(Clone, Debug, PartialEq)]
pub struct SpanEvent {
    /// Chrome-trace `tid`: one track per in-flight engine lane (or per
    /// worker/session for serial-path spans).
    pub track: u64,
    /// Span name — the op-class label plus an optional layer suffix.
    pub name: &'static str,
    pub start: SimTime,
    pub end: SimTime,
}

/// Default span capacity: enough for a full `fdbctl trace` workload
/// while keeping the ring's memory footprint in the tens of KiB.
const DEFAULT_CAPACITY: usize = 4096;

/// The bounded span ring.
pub struct Journal {
    spans: VecDeque<SpanEvent>,
    capacity: usize,
    dropped: u64,
}

impl Default for Journal {
    fn default() -> Journal {
        Journal {
            spans: VecDeque::new(),
            capacity: DEFAULT_CAPACITY,
            dropped: 0,
        }
    }
}

impl Journal {
    pub fn new() -> Journal {
        Journal::default()
    }

    pub fn set_capacity(&mut self, cap: usize) {
        self.capacity = cap.max(1);
        while self.spans.len() > self.capacity {
            self.spans.pop_front();
            self.dropped += 1;
        }
    }

    pub fn record(&mut self, track: u64, name: &'static str, start: SimTime, end: SimTime) {
        if self.spans.len() >= self.capacity {
            self.spans.pop_front();
            self.dropped += 1;
        }
        self.spans.push_back(SpanEvent {
            track,
            name,
            start,
            end,
        });
    }

    pub fn len(&self) -> usize {
        self.spans.len()
    }

    pub fn is_empty(&self) -> bool {
        self.spans.is_empty()
    }

    /// Spans dropped to the ring bound (oldest-first eviction).
    pub fn dropped(&self) -> u64 {
        self.dropped
    }

    pub fn spans(&self) -> impl Iterator<Item = &SpanEvent> {
        self.spans.iter()
    }

    /// Export as a Chrome trace-event JSON array: complete (`"ph":"X"`)
    /// events with microsecond `ts`/`dur`, `pid` 0, and the span track
    /// as `tid`. Zero-duration spans are widened to 1µs so instant ops
    /// on a virtual-time-free backend stay visible in the viewer.
    pub fn chrome_trace(&self) -> Json {
        let events: Vec<Json> = self
            .spans
            .iter()
            .map(|s| {
                let ts = s.start.as_nanos() as f64 / 1_000.0;
                let dur = s.end.saturating_sub(s.start).as_nanos() as f64 / 1_000.0;
                Json::obj()
                    .set("name", s.name)
                    .set("cat", "fdb")
                    .set("ph", "X")
                    .set("ts", ts)
                    .set("dur", dur.max(1.0))
                    .set("pid", 0u64)
                    .set("tid", s.track)
            })
            .collect();
        Json::Arr(events)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ring_drops_oldest_and_counts() {
        let mut j = Journal::new();
        j.set_capacity(3);
        for i in 0..5u64 {
            j.record(0, "data-read", SimTime::micros(i), SimTime::micros(i + 1));
        }
        assert_eq!(j.len(), 3);
        assert_eq!(j.dropped(), 2);
        // oldest evicted: the surviving spans start at 2,3,4
        let starts: Vec<u64> = j.spans().map(|s| s.start.as_nanos() / 1_000).collect();
        assert_eq!(starts, vec![2, 3, 4]);
    }

    #[test]
    fn shrinking_capacity_evicts() {
        let mut j = Journal::new();
        for i in 0..10u64 {
            j.record(1, "flush", SimTime::micros(i), SimTime::micros(i));
        }
        j.set_capacity(4);
        assert_eq!(j.len(), 4);
        assert_eq!(j.dropped(), 6);
    }

    #[test]
    fn chrome_trace_shape() {
        let mut j = Journal::new();
        j.record(
            7,
            "data-read",
            SimTime::micros(100),
            SimTime::micros(350),
        );
        j.record(2, "lookup", SimTime::micros(10), SimTime::micros(10));
        let trace = j.chrome_trace();
        let events = trace.as_arr().unwrap();
        assert_eq!(events.len(), 2);
        let e = &events[0];
        assert_eq!(e.get("name").unwrap().as_str(), Some("data-read"));
        assert_eq!(e.get("ph").unwrap().as_str(), Some("X"));
        assert_eq!(e.get("ts").unwrap().as_f64(), Some(100.0));
        assert_eq!(e.get("dur").unwrap().as_f64(), Some(250.0));
        assert_eq!(e.get("pid").unwrap().as_f64(), Some(0.0));
        assert_eq!(e.get("tid").unwrap().as_f64(), Some(7.0));
        // zero-duration spans widened to 1µs, never 0
        assert_eq!(events[1].get("dur").unwrap().as_f64(), Some(1.0));
        // the export round-trips through the offline JSON parser
        assert!(Json::parse(&trace.to_string()).is_ok());
    }

    #[test]
    fn empty_journal_exports_empty_array() {
        let j = Journal::new();
        assert!(j.is_empty());
        assert_eq!(j.chrome_trace().to_string(), "[]");
    }
}
