//! [`MetricsRegistry`]: named counters, gauges, and exact-sample
//! histograms with log2-bucketed exposition, plus the slow-op log and
//! the span journal. Clone-cheap (`Rc<RefCell<..>>`, same pattern as
//! [`crate::sim::trace::Trace`]); handles minted once and recorded
//! through directly on hot paths.

use std::cell::{Cell, RefCell};
use std::collections::BTreeMap;
use std::rc::Rc;

use crate::sim::time::SimTime;
use crate::sim::trace::OpClass;
use crate::util::json::Json;
use crate::util::stats::nearest_rank_index;

use super::journal::Journal;

/// A monotonically increasing counter handle. Clones share the value.
#[derive(Clone, Default)]
pub struct Counter(Rc<Cell<u64>>);

impl Counter {
    pub fn new() -> Counter {
        Counter::default()
    }

    pub fn add(&self, n: u64) {
        self.0.set(self.0.get() + n);
    }

    pub fn inc(&self) {
        self.add(1);
    }

    pub fn get(&self) -> u64 {
        self.0.get()
    }
}

/// A last/peak-value gauge handle. Clones share the value.
#[derive(Clone, Default)]
pub struct Gauge(Rc<Cell<u64>>);

impl Gauge {
    pub fn set(&self, v: u64) {
        self.0.set(v);
    }

    /// Keep the maximum ever set — peak instrumentation.
    pub fn set_max(&self, v: u64) {
        if v > self.0.get() {
            self.0.set(v);
        }
    }

    pub fn get(&self) -> u64 {
        self.0.get()
    }
}

/// A histogram handle over exact `u64` samples (latency nanoseconds or
/// byte sizes). Observation is a `Vec` push; bucketing and percentiles
/// are computed at readout, never on the hot path.
#[derive(Clone, Default)]
pub struct Hist(Rc<RefCell<HistInner>>);

#[derive(Default)]
struct HistInner {
    samples: Vec<u64>,
    sum: u64,
}

impl Hist {
    pub fn observe(&self, v: u64) {
        let mut inner = self.0.borrow_mut();
        inner.samples.push(v);
        inner.sum += v;
    }

    pub fn observe_duration(&self, d: SimTime) {
        self.observe(d.as_nanos());
    }

    pub fn count(&self) -> u64 {
        self.0.borrow().samples.len() as u64
    }

    pub fn snapshot(&self) -> HistogramSnapshot {
        let inner = self.0.borrow();
        let mut sorted = inner.samples.clone();
        sorted.sort_unstable();
        HistogramSnapshot {
            sorted,
            sum: inner.sum,
        }
    }
}

/// Point-in-time view of one histogram: exact nearest-rank percentiles
/// plus log2 buckets for exposition.
#[derive(Clone, Debug)]
pub struct HistogramSnapshot {
    sorted: Vec<u64>,
    sum: u64,
}

impl HistogramSnapshot {
    pub fn count(&self) -> u64 {
        self.sorted.len() as u64
    }

    pub fn sum(&self) -> u64 {
        self.sum
    }

    pub fn min(&self) -> u64 {
        self.sorted.first().copied().unwrap_or(0)
    }

    pub fn max(&self) -> u64 {
        self.sorted.last().copied().unwrap_or(0)
    }

    pub fn mean(&self) -> f64 {
        if self.sorted.is_empty() {
            return 0.0;
        }
        self.sum as f64 / self.sorted.len() as f64
    }

    /// Exact nearest-rank percentile (`p` in [0,100]) — the SAME rule
    /// as [`crate::util::stats::Summary::percentile`], so bench and
    /// telemetry agree on one sample.
    pub fn percentile(&self, p: f64) -> u64 {
        if self.sorted.is_empty() {
            return 0;
        }
        self.sorted[nearest_rank_index(p, self.sorted.len())]
    }

    /// Occupied log2 buckets as `(inclusive upper bound, count)` pairs,
    /// ascending. A sample `v` lands in the bucket whose bound is
    /// `next_power_of_two(max(v, 1))`.
    pub fn log2_buckets(&self) -> Vec<(u64, u64)> {
        let mut buckets: BTreeMap<u64, u64> = BTreeMap::new();
        for &v in &self.sorted {
            let bound = v.max(1).next_power_of_two();
            *buckets.entry(bound).or_insert(0) += 1;
        }
        buckets.into_iter().collect()
    }

    pub fn to_json(&self) -> Json {
        let buckets: Vec<Json> = self
            .log2_buckets()
            .into_iter()
            .map(|(le, n)| Json::Arr(vec![Json::from(le), Json::from(n)]))
            .collect();
        Json::obj()
            .set("count", self.count())
            .set("sum", self.sum())
            .set("min", self.min())
            .set("max", self.max())
            .set("mean", self.mean())
            .set("p50", self.percentile(50.0))
            .set("p95", self.percentile(95.0))
            .set("p99", self.percentile(99.0))
            .set("p999", self.percentile(99.9))
            .set("buckets", buckets)
    }
}

/// One entry of the slow-op log: an operation that exceeded
/// `IoProfile::slow_op_us`.
#[derive(Clone, Debug)]
pub struct SlowOp {
    pub class: OpClass,
    /// layer/backend label the op ran against
    pub backend: String,
    pub duration: SimTime,
}

/// Cap on retained slow-op entries (overflow counted, newest dropped —
/// the first slow ops are the diagnostic ones).
const SLOW_OP_CAP: usize = 256;

#[derive(Default)]
struct RegistryInner {
    counters: BTreeMap<String, Counter>,
    gauges: BTreeMap<String, Gauge>,
    hists: BTreeMap<String, Hist>,
    journal: Journal,
    slow_ops: Vec<SlowOp>,
    slow_dropped: u64,
}

/// The metrics registry. Clone-cheap; one per `Fdb` (shareable across
/// instances of a deployment by attaching the same registry through
/// [`crate::fdb::FdbBuilder::metrics`]).
#[derive(Clone, Default)]
pub struct MetricsRegistry {
    inner: Rc<RefCell<RegistryInner>>,
}

impl MetricsRegistry {
    pub fn new() -> MetricsRegistry {
        MetricsRegistry::default()
    }

    /// Get-or-create a counter handle. Bind once, record through the
    /// handle — not through the registry — on hot paths.
    pub fn counter(&self, name: &str) -> Counter {
        self.inner
            .borrow_mut()
            .counters
            .entry(name.to_string())
            .or_default()
            .clone()
    }

    pub fn gauge(&self, name: &str) -> Gauge {
        self.inner
            .borrow_mut()
            .gauges
            .entry(name.to_string())
            .or_default()
            .clone()
    }

    pub fn histogram(&self, name: &str) -> Hist {
        self.inner
            .borrow_mut()
            .hists
            .entry(name.to_string())
            .or_default()
            .clone()
    }

    /// Current value of a counter (0 if never created).
    pub fn counter_value(&self, name: &str) -> u64 {
        self.inner
            .borrow()
            .counters
            .get(name)
            .map(|c| c.get())
            .unwrap_or(0)
    }

    pub fn gauge_value(&self, name: &str) -> u64 {
        self.inner
            .borrow()
            .gauges
            .get(name)
            .map(|g| g.get())
            .unwrap_or(0)
    }

    /// Snapshot of a histogram, `None` if it was never created.
    pub fn hist(&self, name: &str) -> Option<HistogramSnapshot> {
        self.inner.borrow().hists.get(name).map(|h| h.snapshot())
    }

    /// Names of all histograms with at least one sample, sorted.
    pub fn hist_names(&self) -> Vec<String> {
        self.inner
            .borrow()
            .hists
            .iter()
            .filter(|(_, h)| h.count() > 0)
            .map(|(k, _)| k.clone())
            .collect()
    }

    // ---- slow-op log ----

    pub fn record_slow_op(&self, class: OpClass, backend: &str, duration: SimTime) {
        let mut inner = self.inner.borrow_mut();
        if inner.slow_ops.len() >= SLOW_OP_CAP {
            inner.slow_dropped += 1;
            return;
        }
        inner.slow_ops.push(SlowOp {
            class,
            backend: backend.to_string(),
            duration,
        });
    }

    pub fn slow_ops(&self) -> Vec<SlowOp> {
        self.inner.borrow().slow_ops.clone()
    }

    pub fn slow_ops_dropped(&self) -> u64 {
        self.inner.borrow().slow_dropped
    }

    // ---- span journal ----

    /// Record one op span into the bounded journal ring (`track` is the
    /// Chrome-trace tid — one per in-flight engine lane).
    pub fn record_span(&self, track: u64, name: &'static str, start: SimTime, end: SimTime) {
        self.inner.borrow_mut().journal.record(track, name, start, end);
    }

    pub fn set_journal_capacity(&self, cap: usize) {
        self.inner.borrow_mut().journal.set_capacity(cap);
    }

    pub fn journal_len(&self) -> usize {
        self.inner.borrow().journal.len()
    }

    pub fn journal_dropped(&self) -> u64 {
        self.inner.borrow().journal.dropped()
    }

    /// Export the journal as Chrome trace-event JSON (an array of
    /// complete `"ph": "X"` events; load in `chrome://tracing`).
    pub fn chrome_trace(&self) -> Json {
        self.inner.borrow().journal.chrome_trace()
    }

    // ---- exposition ----

    /// Dump the whole registry as JSON (`--metrics <path>`).
    pub fn to_json(&self) -> Json {
        let inner = self.inner.borrow();
        let mut counters = Json::obj();
        for (k, c) in &inner.counters {
            counters = counters.set(k, c.get());
        }
        let mut gauges = Json::obj();
        for (k, g) in &inner.gauges {
            gauges = gauges.set(k, g.get());
        }
        let mut hists = Json::obj();
        for (k, h) in &inner.hists {
            if h.count() > 0 {
                hists = hists.set(k, h.snapshot().to_json());
            }
        }
        let slow: Vec<Json> = inner
            .slow_ops
            .iter()
            .map(|s| {
                Json::obj()
                    .set("class", s.class.label())
                    .set("backend", s.backend.as_str())
                    .set("duration_us", s.duration.as_nanos() / 1_000)
            })
            .collect();
        Json::obj()
            .set("counters", counters)
            .set("gauges", gauges)
            .set("histograms", hists)
            .set("slow_ops", slow)
            .set(
                "journal",
                Json::obj()
                    .set("spans", inner.journal.len())
                    .set("dropped", inner.journal.dropped()),
            )
    }

    /// Render the registry as Prometheus-style text exposition
    /// (`fdbctl metrics`): counters and gauges as plain samples,
    /// histograms as quantile lines + cumulative log2 `_bucket` lines
    /// with `_sum`/`_count`.
    pub fn render_prometheus(&self) -> String {
        fn sanitize(name: &str) -> String {
            let mut out = String::with_capacity(name.len() + 4);
            out.push_str("fdb_");
            for c in name.chars() {
                if c.is_ascii_alphanumeric() {
                    out.push(c);
                } else {
                    out.push('_');
                }
            }
            out
        }
        let inner = self.inner.borrow();
        let mut out = String::new();
        for (k, c) in &inner.counters {
            let n = sanitize(k);
            out.push_str(&format!("# TYPE {n} counter\n{n} {}\n", c.get()));
        }
        for (k, g) in &inner.gauges {
            let n = sanitize(k);
            out.push_str(&format!("# TYPE {n} gauge\n{n} {}\n", g.get()));
        }
        for (k, h) in &inner.hists {
            if h.count() == 0 {
                continue;
            }
            let snap = h.snapshot();
            let n = sanitize(k);
            out.push_str(&format!("# TYPE {n} histogram\n"));
            let mut cumulative = 0u64;
            for (le, count) in snap.log2_buckets() {
                cumulative += count;
                out.push_str(&format!("{n}_bucket{{le=\"{le}\"}} {cumulative}\n"));
            }
            out.push_str(&format!("{n}_bucket{{le=\"+Inf\"}} {}\n", snap.count()));
            for (q, p) in [("0.5", 50.0), ("0.95", 95.0), ("0.99", 99.0), ("0.999", 99.9)] {
                out.push_str(&format!(
                    "{n}{{quantile=\"{q}\"}} {}\n",
                    snap.percentile(p)
                ));
            }
            out.push_str(&format!("{n}_sum {}\n", snap.sum()));
            out.push_str(&format!("{n}_count {}\n", snap.count()));
        }
        out
    }
}

/// Pre-bound per-op-class probe: wait + service histograms and outcome
/// counters. One name-map lookup at bind time, zero per op.
#[derive(Clone)]
pub struct OpProbe {
    pub wait: Hist,
    pub service: Hist,
    pub ok: Counter,
    pub err: Counter,
    pub fault: Counter,
}

/// The engine's pre-bound metric handles, one [`OpProbe`] per
/// [`OpClass`] plus bytes and in-flight peak. Minted by
/// [`EngineMetrics::bind`] when a registry is attached.
pub struct EngineMetrics {
    probes: Vec<OpProbe>,
    pub bytes_read: Counter,
    pub bytes_written: Counter,
    pub inflight_peak: Gauge,
}

impl EngineMetrics {
    pub fn bind(reg: &MetricsRegistry) -> EngineMetrics {
        let probes = OpClass::ALL
            .iter()
            .map(|c| {
                let l = c.label();
                OpProbe {
                    wait: reg.histogram(&format!("engine.wait.{l}")),
                    service: reg.histogram(&format!("engine.service.{l}")),
                    ok: reg.counter(&format!("engine.ops.{l}.ok")),
                    err: reg.counter(&format!("engine.ops.{l}.err")),
                    fault: reg.counter(&format!("engine.ops.{l}.fault")),
                }
            })
            .collect();
        EngineMetrics {
            probes,
            bytes_read: reg.counter("engine.bytes_read"),
            bytes_written: reg.counter("engine.bytes_written"),
            inflight_peak: reg.gauge("engine.inflight_peak"),
        }
    }

    pub fn probe(&self, class: OpClass) -> &OpProbe {
        let idx = OpClass::ALL
            .iter()
            .position(|&c| c == class)
            .expect("OpClass::ALL covers every class");
        &self.probes[idx]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counters_and_gauges_share_handles() {
        let reg = MetricsRegistry::new();
        let c = reg.counter("x");
        c.add(3);
        reg.counter("x").inc();
        assert_eq!(reg.counter_value("x"), 4);
        let g = reg.gauge("peak");
        g.set_max(7);
        g.set_max(3); // lower: ignored
        assert_eq!(reg.gauge_value("peak"), 7);
        assert_eq!(reg.counter_value("never"), 0);
    }

    #[test]
    fn histogram_percentiles_match_nearest_rank() {
        let reg = MetricsRegistry::new();
        let h = reg.histogram("lat");
        for v in 1..=100u64 {
            h.observe(v);
        }
        let snap = reg.hist("lat").unwrap();
        assert_eq!(snap.count(), 100);
        assert_eq!(snap.percentile(50.0), 50);
        assert_eq!(snap.percentile(99.0), 99);
        assert_eq!(snap.percentile(99.9), 100);
        assert_eq!(snap.min(), 1);
        assert_eq!(snap.max(), 100);
        assert!((snap.mean() - 50.5).abs() < 1e-9);
    }

    #[test]
    fn histogram_percentiles_agree_with_bench_summary() {
        // the acceptance contract: telemetry p99 == bench p99 on the
        // same sample, because both use the same nearest-rank rule
        let reg = MetricsRegistry::new();
        let h = reg.histogram("lat");
        let mut s = crate::util::stats::Summary::new();
        // awkward sample sizes where interpolating implementations
        // would diverge
        let samples: Vec<u64> = vec![5, 9, 1, 22, 17, 3, 8];
        for &v in &samples {
            h.observe(v);
            s.add(v as f64);
        }
        let snap = reg.hist("lat").unwrap();
        for p in [0.0, 50.0, 95.0, 99.0, 99.9, 100.0] {
            assert_eq!(snap.percentile(p) as f64, s.percentile(p), "p{p}");
        }
    }

    #[test]
    fn log2_buckets_partition_the_sample() {
        let reg = MetricsRegistry::new();
        let h = reg.histogram("sz");
        for v in [0, 1, 2, 3, 4, 5, 1000, 1024, 1025] {
            h.observe(v);
        }
        let snap = reg.hist("sz").unwrap();
        let buckets = snap.log2_buckets();
        let total: u64 = buckets.iter().map(|(_, n)| n).sum();
        assert_eq!(total, snap.count());
        // 0 and 1 share the le=1 bucket; 1000/1024 land in le=1024
        assert!(buckets.contains(&(1, 2)));
        assert!(buckets.contains(&(1024, 2)));
        assert!(buckets.contains(&(2048, 1)));
        // bounds ascend
        assert!(buckets.windows(2).all(|w| w[0].0 < w[1].0));
    }

    #[test]
    fn json_and_prometheus_expose_the_same_values() {
        let reg = MetricsRegistry::new();
        reg.counter("ops.total").add(11);
        reg.gauge("engine.inflight_peak").set_max(4);
        let h = reg.histogram("engine.service.data-read");
        h.observe(100);
        h.observe(200);
        let j = reg.to_json();
        assert_eq!(
            j.get("counters").unwrap().get("ops.total").unwrap().as_f64(),
            Some(11.0)
        );
        let hist = j.get("histograms").unwrap().get("engine.service.data-read").unwrap();
        assert_eq!(hist.get("count").unwrap().as_f64(), Some(2.0));
        assert_eq!(hist.get("p99").unwrap().as_f64(), Some(200.0));
        let text = reg.render_prometheus();
        assert!(text.contains("fdb_ops_total 11"));
        assert!(text.contains("fdb_engine_inflight_peak 4"));
        assert!(text.contains("fdb_engine_service_data_read_count 2"));
        assert!(text.contains("fdb_engine_service_data_read{quantile=\"0.99\"} 200"));
        // the JSON round-trips through the offline parser
        assert!(Json::parse(&j.to_string()).is_ok());
    }

    #[test]
    fn slow_op_log_caps_and_counts_overflow() {
        let reg = MetricsRegistry::new();
        for i in 0..(SLOW_OP_CAP + 5) {
            reg.record_slow_op(OpClass::DataRead, "posix", SimTime::micros(i as u64));
        }
        assert_eq!(reg.slow_ops().len(), SLOW_OP_CAP);
        assert_eq!(reg.slow_ops_dropped(), 5);
    }

    #[test]
    fn engine_metrics_probe_per_class() {
        let reg = MetricsRegistry::new();
        let em = EngineMetrics::bind(&reg);
        em.probe(OpClass::DataRead).ok.inc();
        em.probe(OpClass::DataRead)
            .service
            .observe_duration(SimTime::micros(5));
        em.probe(OpClass::DataWrite).fault.inc();
        assert_eq!(reg.counter_value("engine.ops.data-read.ok"), 1);
        assert_eq!(reg.counter_value("engine.ops.data-write.fault"), 1);
        assert_eq!(reg.hist("engine.service.data-read").unwrap().count(), 1);
    }

    #[test]
    fn empty_histograms_are_omitted_from_exposition() {
        let reg = MetricsRegistry::new();
        reg.histogram("engine.wait.lock"); // bound but never observed
        assert!(reg.hist_names().is_empty());
        assert!(!reg.render_prometheus().contains("engine_wait_lock"));
        let j = reg.to_json();
        assert_eq!(j.get("histograms"), Some(&Json::obj()));
    }
}
