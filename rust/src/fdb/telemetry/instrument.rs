//! [`InstrumentStore`] / [`InstrumentCatalogue`]: metrics-recording
//! wrapper shims in the style of [`crate::fdb::fault::FaultStore`].
//!
//! Each shim carries a **layer label** (assigned by the builder while
//! recursing the [`crate::fdb::BackendConfig`] tree: `posix`,
//! `replicated.r0`, `tiered.front`, `sharded.s2.…`) and a set of
//! handles pre-bound from the registry at construction, so a composed
//! `sharded(tiered(posix, replicated(lustre)))` stack reports
//! per-replica read latency, per-tier archive counts, and per-shard
//! lookups instead of one blended number. Recording is a handle touch
//! per op — no registry lookups on the hot path.
//!
//! Latency histograms need a clock: the shim records durations only
//! when built with a [`Sim`] handle (counters and byte totals always
//! record). All non-instrumented surface (direct-retrieve, wipe, lock
//! time, group hooks, recovery) passes through untouched, so metrics
//! on vs. off is behaviourally identical.

use std::rc::Rc;

use crate::fdb::backend::{
    Catalogue, CatalogueSession, LocalBoxFuture, Store, StoreSession,
};
use crate::fdb::datahandle::DataHandle;
use crate::fdb::fault::wal::RecoveryStats;
use crate::fdb::key::Key;
use crate::fdb::location::FieldLocation;
use crate::fdb::request::Request;
use crate::fdb::FdbError;
use crate::sim::exec::Sim;
use crate::sim::time::SimTime;
use crate::util::content::Bytes;

use super::is_injected_fault;
use super::registry::{Counter, Hist, MetricsRegistry};

/// Latency + outcome handles for one instrumented method.
#[derive(Clone)]
struct MethodProbe {
    lat: Hist,
    ok: Counter,
    err: Counter,
    fault: Counter,
}

impl MethodProbe {
    fn bind(reg: &MetricsRegistry, name: &str) -> MethodProbe {
        MethodProbe {
            lat: reg.histogram(name),
            ok: reg.counter(&format!("{name}.ok")),
            err: reg.counter(&format!("{name}.err")),
            fault: reg.counter(&format!("{name}.fault")),
        }
    }

    fn observe<T>(&self, dur: Option<SimTime>, result: &Result<T, FdbError>) {
        if let Some(d) = dur {
            self.lat.observe(d.as_nanos());
        }
        match result {
            Ok(_) => self.ok.inc(),
            Err(e) if is_injected_fault(e) => self.fault.inc(),
            Err(_) => self.err.inc(),
        }
    }
}

/// Shared timing context: duration is measurable only with a clock.
#[derive(Clone)]
struct Clock(Option<Sim>);

impl Clock {
    fn start(&self) -> Option<SimTime> {
        self.0.as_ref().map(|s| s.now())
    }

    fn elapsed(&self, t0: Option<SimTime>) -> Option<SimTime> {
        match (t0, self.0.as_ref()) {
            (Some(t0), Some(sim)) => Some(sim.now().saturating_sub(t0)),
            _ => None,
        }
    }
}

/// The pre-bound handle set of one store layer. Clone-cheap (shims and
/// their sessions share one set, like [`FaultStore`]'s shared state).
#[derive(Clone)]
pub struct StoreProbes {
    archive: MethodProbe,
    read: MethodProbe,
    flush: MethodProbe,
    bytes_written: Counter,
    bytes_read: Counter,
}

impl StoreProbes {
    fn bind(reg: &MetricsRegistry, label: &str) -> StoreProbes {
        StoreProbes {
            archive: MethodProbe::bind(reg, &format!("store.{label}.archive")),
            read: MethodProbe::bind(reg, &format!("store.{label}.read")),
            flush: MethodProbe::bind(reg, &format!("store.{label}.flush")),
            bytes_written: reg.counter(&format!("store.{label}.bytes_written")),
            bytes_read: reg.counter(&format!("store.{label}.bytes_read")),
        }
    }
}

/// A metrics-recording [`Store`] wrapper for one labelled layer.
pub struct InstrumentStore {
    inner: Box<dyn Store>,
    probes: Rc<StoreProbes>,
    clock: Clock,
}

impl InstrumentStore {
    pub fn new(
        inner: Box<dyn Store>,
        reg: &MetricsRegistry,
        label: &str,
        sim: Option<&Sim>,
    ) -> InstrumentStore {
        InstrumentStore {
            inner,
            probes: Rc::new(StoreProbes::bind(reg, label)),
            clock: Clock(sim.cloned()),
        }
    }
}

impl Store for InstrumentStore {
    fn name(&self) -> &'static str {
        self.inner.name()
    }

    fn archive<'a>(
        &'a mut self,
        ds: &'a Key,
        colloc: &'a Key,
        id: &'a Key,
        data: Bytes,
    ) -> LocalBoxFuture<'a, Result<FieldLocation, FdbError>> {
        Box::pin(async move {
            let len = data.len();
            let t0 = self.clock.start();
            let result = self.inner.archive(ds, colloc, id, data).await;
            self.probes.archive.observe(self.clock.elapsed(t0), &result);
            if result.is_ok() {
                self.probes.bytes_written.add(len);
            }
            result
        })
    }

    fn flush<'a>(&'a mut self) -> LocalBoxFuture<'a, Result<(), FdbError>> {
        Box::pin(async move {
            let t0 = self.clock.start();
            let result = self.inner.flush().await;
            self.probes.flush.observe(self.clock.elapsed(t0), &result);
            result
        })
    }

    fn read<'a>(
        &'a mut self,
        handle: &'a DataHandle,
    ) -> LocalBoxFuture<'a, Result<Bytes, FdbError>> {
        Box::pin(async move {
            let t0 = self.clock.start();
            let result = self.inner.read(handle).await;
            self.probes.read.observe(self.clock.elapsed(t0), &result);
            if let Ok(b) = &result {
                self.probes.bytes_read.add(b.len());
            }
            result
        })
    }

    fn read_ranges<'a>(
        &'a mut self,
        handles: &'a [DataHandle],
    ) -> LocalBoxFuture<'a, Result<Vec<Bytes>, FdbError>> {
        Box::pin(async move {
            // delegate to the inner vectored path (a loop of `read`
            // here would defeat per-batch container resolution); one
            // latency sample per batch
            let t0 = self.clock.start();
            let result = self.inner.read_ranges(handles).await;
            self.probes.read.observe(self.clock.elapsed(t0), &result);
            if let Ok(bs) = &result {
                self.probes
                    .bytes_read
                    .add(bs.iter().map(|b| b.len()).sum());
            }
            result
        })
    }

    fn read_verified<'a>(
        &'a mut self,
        handle: &'a DataHandle,
        checks: &'a [crate::fdb::scrub::RangeCheck],
    ) -> LocalBoxFuture<'a, Result<Bytes, FdbError>> {
        Box::pin(async move {
            // forwarded (not defaulted) so an inner override — replica
            // failover on corruption — stays in the path; recorded under
            // the same read probe
            let t0 = self.clock.start();
            let result = self.inner.read_verified(handle, checks).await;
            self.probes.read.observe(self.clock.elapsed(t0), &result);
            if let Ok(b) = &result {
                self.probes.bytes_read.add(b.len());
            }
            result
        })
    }

    fn read_ranges_verified<'a>(
        &'a mut self,
        handles: &'a [DataHandle],
        checks: &'a [Vec<crate::fdb::scrub::RangeCheck>],
    ) -> LocalBoxFuture<'a, Result<Vec<Bytes>, FdbError>> {
        Box::pin(async move {
            let t0 = self.clock.start();
            let result = self.inner.read_ranges_verified(handles, checks).await;
            self.probes.read.observe(self.clock.elapsed(t0), &result);
            if let Ok(bs) = &result {
                self.probes
                    .bytes_read
                    .add(bs.iter().map(|b| b.len()).sum());
            }
            result
        })
    }

    fn repair<'a>(
        &'a mut self,
        handle: &'a DataHandle,
        data: Bytes,
    ) -> LocalBoxFuture<'a, Result<bool, FdbError>> {
        self.inner.repair(handle, data)
    }

    fn scrub_field<'a>(
        &'a mut self,
        handle: &'a DataHandle,
        expect_len: u64,
        ck: Option<u64>,
        do_repair: bool,
    ) -> LocalBoxFuture<'a, Result<crate::fdb::scrub::ScrubOutcome, FdbError>> {
        self.inner.scrub_field(handle, expect_len, ck, do_repair)
    }

    fn scrub_inventory<'a>(
        &'a mut self,
        ds: &'a Key,
    ) -> LocalBoxFuture<'a, Option<Vec<(String, u64)>>> {
        self.inner.scrub_inventory(ds)
    }

    fn quarantine_object<'a>(
        &'a mut self,
        ds: &'a Key,
        container: &'a str,
    ) -> LocalBoxFuture<'a, Result<bool, FdbError>> {
        self.inner.quarantine_object(ds, container)
    }

    fn direct_retrieve_enabled(&self) -> bool {
        self.inner.direct_retrieve_enabled()
    }

    fn retrieve_direct<'a>(
        &'a mut self,
        ds: &'a Key,
        id: &'a Key,
    ) -> LocalBoxFuture<'a, Option<FieldLocation>> {
        self.inner.retrieve_direct(ds, id)
    }

    fn supports_wipe(&self) -> bool {
        self.inner.supports_wipe()
    }

    fn wipe_dataset<'a>(&'a mut self, ds: &'a Key) -> LocalBoxFuture<'a, bool> {
        self.inner.wipe_dataset(ds)
    }

    fn take_lock_time(&self) -> SimTime {
        self.inner.take_lock_time()
    }

    fn session(&mut self) -> Option<Box<dyn StoreSession>> {
        // sessions record into the SAME layer handles as the parent:
        // per-layer metrics aggregate over every client of the layer
        let inner = self.inner.session()?;
        Some(Box::new(InstrumentStore {
            inner: inner.into_store(),
            probes: self.probes.clone(),
            clock: self.clock.clone(),
        }))
    }
}

/// The pre-bound handle set of one catalogue layer.
#[derive(Clone)]
pub struct CatalogueProbes {
    archive: MethodProbe,
    flush: MethodProbe,
    lookup_lat: Hist,
    lookup_hit: Counter,
    lookup_miss: Counter,
    list_ops: Counter,
}

impl CatalogueProbes {
    fn bind(reg: &MetricsRegistry, label: &str) -> CatalogueProbes {
        CatalogueProbes {
            archive: MethodProbe::bind(reg, &format!("cat.{label}.archive")),
            flush: MethodProbe::bind(reg, &format!("cat.{label}.flush")),
            lookup_lat: reg.histogram(&format!("cat.{label}.lookup")),
            lookup_hit: reg.counter(&format!("cat.{label}.lookup.hit")),
            lookup_miss: reg.counter(&format!("cat.{label}.lookup.miss")),
            list_ops: reg.counter(&format!("cat.{label}.list.ops")),
        }
    }
}

/// A metrics-recording [`Catalogue`] wrapper for one labelled layer.
pub struct InstrumentCatalogue {
    inner: Box<dyn Catalogue>,
    probes: Rc<CatalogueProbes>,
    clock: Clock,
}

impl InstrumentCatalogue {
    pub fn new(
        inner: Box<dyn Catalogue>,
        reg: &MetricsRegistry,
        label: &str,
        sim: Option<&Sim>,
    ) -> InstrumentCatalogue {
        InstrumentCatalogue {
            inner,
            probes: Rc::new(CatalogueProbes::bind(reg, label)),
            clock: Clock(sim.cloned()),
        }
    }
}

impl Catalogue for InstrumentCatalogue {
    fn name(&self) -> &'static str {
        self.inner.name()
    }

    fn archive<'a>(
        &'a mut self,
        ds: &'a Key,
        colloc: &'a Key,
        elem: &'a Key,
        id: &'a Key,
        loc: &'a FieldLocation,
    ) -> LocalBoxFuture<'a, Result<(), FdbError>> {
        Box::pin(async move {
            let t0 = self.clock.start();
            let result = self.inner.archive(ds, colloc, elem, id, loc).await;
            self.probes.archive.observe(self.clock.elapsed(t0), &result);
            result
        })
    }

    fn flush<'a>(&'a mut self) -> LocalBoxFuture<'a, Result<(), FdbError>> {
        Box::pin(async move {
            let t0 = self.clock.start();
            let result = self.inner.flush().await;
            self.probes.flush.observe(self.clock.elapsed(t0), &result);
            result
        })
    }

    fn forget<'a>(
        &'a mut self,
        ds: &'a Key,
        colloc: &'a Key,
        elem: &'a Key,
        id: &'a Key,
    ) -> LocalBoxFuture<'a, Result<bool, FdbError>> {
        // forwarded (not defaulted): the default is a no-op `Ok(false)`,
        // which would silently disable fsck ghost-drops through an
        // instrumented catalogue
        self.inner.forget(ds, colloc, elem, id)
    }

    fn close<'a>(&'a mut self) -> LocalBoxFuture<'a, Result<(), FdbError>> {
        self.inner.close()
    }

    fn recover_dataset<'a>(
        &'a mut self,
        ds: &'a Key,
    ) -> LocalBoxFuture<'a, Result<RecoveryStats, FdbError>> {
        self.inner.recover_dataset(ds)
    }

    fn retrieve<'a>(
        &'a mut self,
        ds: &'a Key,
        colloc: &'a Key,
        elem: &'a Key,
        id: &'a Key,
    ) -> LocalBoxFuture<'a, Option<FieldLocation>> {
        Box::pin(async move {
            let t0 = self.clock.start();
            let result = self.inner.retrieve(ds, colloc, elem, id).await;
            if let Some(d) = self.clock.elapsed(t0) {
                self.probes.lookup_lat.observe(d.as_nanos());
            }
            match &result {
                Some(_) => self.probes.lookup_hit.inc(),
                None => self.probes.lookup_miss.inc(),
            }
            result
        })
    }

    fn axis<'a>(
        &'a mut self,
        ds: &'a Key,
        colloc: &'a Key,
        dim: &'a str,
    ) -> LocalBoxFuture<'a, Vec<String>> {
        self.inner.axis(ds, colloc, dim)
    }

    fn list<'a>(
        &'a mut self,
        ds: &'a Key,
        request: &'a Request,
    ) -> LocalBoxFuture<'a, Vec<(Key, FieldLocation)>> {
        self.probes.list_ops.inc();
        self.inner.list(ds, request)
    }

    fn invalidate_preload(&mut self, ds: &Key) {
        self.inner.invalidate_preload(ds);
    }

    fn deregister_dataset<'a>(&'a mut self, ds: &'a Key) -> LocalBoxFuture<'a, ()> {
        self.inner.deregister_dataset(ds)
    }

    fn take_lock_time(&self) -> SimTime {
        self.inner.take_lock_time()
    }

    fn session(&mut self) -> Option<Box<dyn CatalogueSession>> {
        let inner = self.inner.session()?;
        Some(Box::new(InstrumentCatalogue {
            inner: inner.into_catalogue(),
            probes: self.probes.clone(),
            clock: self.clock.clone(),
        }))
    }

    fn begin_archive_group(&mut self) {
        self.inner.begin_archive_group();
    }

    fn end_archive_group<'a>(&'a mut self) -> LocalBoxFuture<'a, Result<(), FdbError>> {
        self.inner.end_archive_group()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fdb::backend::{block_on_ready as block_on, NullCatalogue, NullStore};

    fn reg_and_store() -> (MetricsRegistry, InstrumentStore) {
        let reg = MetricsRegistry::new();
        let s = InstrumentStore::new(Box::new(NullStore), &reg, "posix", None);
        (reg, s)
    }

    #[test]
    fn store_ops_count_and_accumulate_bytes() {
        let (reg, mut s) = reg_and_store();
        let ds = Key::new();
        let id = Key::of(&[("step", "1")]);
        let loc = block_on(s.archive(&ds, &ds, &id, Bytes::virt(64, 1))).unwrap();
        let h = DataHandle::from_location(&loc);
        block_on(s.read(&h)).unwrap();
        block_on(s.flush()).unwrap();
        assert_eq!(reg.counter_value("store.posix.archive.ok"), 1);
        assert_eq!(reg.counter_value("store.posix.bytes_written"), 64);
        assert_eq!(reg.counter_value("store.posix.read.ok"), 1);
        assert_eq!(reg.counter_value("store.posix.bytes_read"), 64);
        assert_eq!(reg.counter_value("store.posix.flush.ok"), 1);
        // no clock: counters record, latency histograms stay empty
        assert!(reg.hist("store.posix.read").is_none() || reg.hist("store.posix.read").unwrap().count() == 0);
    }

    #[test]
    fn mismatched_read_counts_as_organic_error_not_fault() {
        let (reg, mut s) = reg_and_store();
        let h = DataHandle::Posix {
            path: "/f".into(),
            ranges: vec![(0, 4)],
        };
        assert!(block_on(s.read(&h)).is_err());
        assert_eq!(reg.counter_value("store.posix.read.err"), 1);
        assert_eq!(reg.counter_value("store.posix.read.fault"), 0);
        assert_eq!(reg.counter_value("store.posix.bytes_read"), 0);
    }

    #[test]
    fn injected_faults_count_separately() {
        use crate::fdb::fault::plan::{FaultAction, FaultClass, FaultPlan};
        use crate::fdb::fault::FaultStore;
        let reg = MetricsRegistry::new();
        let plan =
            FaultPlan::new(3).with_rule(FaultClass::Read, FaultAction::FailStop { after: 0 });
        let fault = FaultStore::new(Box::new(NullStore), plan.build_state(None));
        let mut s = InstrumentStore::new(Box::new(fault), &reg, "r1", None);
        let h = DataHandle::Null { length: 8 };
        assert!(block_on(s.read(&h)).is_err());
        assert_eq!(reg.counter_value("store.r1.read.fault"), 1);
        assert_eq!(reg.counter_value("store.r1.read.err"), 0);
    }

    #[test]
    fn sessions_record_into_the_parents_layer() {
        let (reg, mut s) = reg_and_store();
        let mut session = s.session().expect("null store has sessions");
        let h = DataHandle::Null { length: 16 };
        block_on(session.read(&h)).unwrap();
        block_on(s.read(&h)).unwrap();
        // one layer, two clients, one aggregate
        assert_eq!(reg.counter_value("store.posix.read.ok"), 2);
        assert_eq!(reg.counter_value("store.posix.bytes_read"), 32);
    }

    #[test]
    fn catalogue_lookups_split_hit_and_miss() {
        let reg = MetricsRegistry::new();
        let mut c =
            InstrumentCatalogue::new(Box::new(NullCatalogue::new()), &reg, "s0", None);
        let ds = Key::new();
        let id = Key::of(&[("step", "1")]);
        let loc = FieldLocation::Null { length: 4 };
        block_on(c.archive(&ds, &ds, &id, &id, &loc)).unwrap();
        assert!(block_on(c.retrieve(&ds, &ds, &id, &id)).is_some());
        let missing = Key::of(&[("step", "9")]);
        assert!(block_on(c.retrieve(&ds, &ds, &missing, &missing)).is_none());
        block_on(c.list(&ds, &Request::parse("").unwrap()));
        assert_eq!(reg.counter_value("cat.s0.archive.ok"), 1);
        assert_eq!(reg.counter_value("cat.s0.lookup.hit"), 1);
        assert_eq!(reg.counter_value("cat.s0.lookup.miss"), 1);
        assert_eq!(reg.counter_value("cat.s0.list.ops"), 1);
    }

    #[test]
    fn latency_records_with_a_clock() {
        use crate::sim::exec::Sim;
        let sim = Sim::new();
        let reg = MetricsRegistry::new();
        // a store whose reads cost virtual time: FaultStore slow rule
        use crate::fdb::fault::plan::{FaultAction, FaultClass, FaultPlan};
        use crate::fdb::fault::FaultStore;
        let plan =
            FaultPlan::new(3).with_rule(FaultClass::Read, FaultAction::Slow { micros: 250 });
        let fault = FaultStore::new(Box::new(NullStore), plan.build_state(Some(&sim)));
        let store = std::rc::Rc::new(std::cell::RefCell::new(InstrumentStore::new(
            Box::new(fault),
            &reg,
            "lustre",
            Some(&sim),
        )));
        let sim2 = sim.clone();
        let store2 = store.clone();
        sim.spawn(async move {
            let _ = &sim2;
            let h = DataHandle::Null { length: 8 };
            store2.borrow_mut().read(&h).await.unwrap();
        });
        sim.run();
        let snap = reg.hist("store.lustre.read").unwrap();
        assert_eq!(snap.count(), 1);
        assert_eq!(snap.percentile(50.0), SimTime::micros(250).as_nanos());
    }
}
