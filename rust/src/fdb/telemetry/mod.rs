//! The telemetry subsystem: one observability layer for the whole I/O
//! stack, replacing the scattered one-off probes (`io_inflight_peak`,
//! `plan_stats`, `wal_sync_count`, per-run `Trace` totals) with a
//! single place to ask "where did this batch's time go, per backend
//! layer, at p99".
//!
//! Three cooperating pieces:
//!
//! * [`MetricsRegistry`] — named counters, gauges, and latency/size
//!   histograms. Histograms keep exact samples and report **log2
//!   buckets** for exposition plus exact p50/p95/p99/p999 by the same
//!   nearest-rank rule as [`crate::util::stats::Summary::percentile`],
//!   so a bench p99 and a registry p99 over one sample agree to the
//!   nanosecond. Hot paths pre-bind handles ([`Counter`], [`Gauge`],
//!   [`Hist`]) at attach time — recording is one `Cell`/`Vec` touch,
//!   no name lookup per op.
//! * [`instrument::InstrumentStore`] / [`instrument::InstrumentCatalogue`]
//!   — wrapper shims in the style of [`crate::fdb::fault::FaultStore`]
//!   that label every layer of a composed backend stack: a
//!   `sharded(tiered(posix, replicated(lustre)))` deployment reports
//!   per-replica read latency, front-tier hit counts, and per-shard
//!   lookups instead of one blended number. The builder wires them
//!   automatically when [`crate::fdb::FdbBuilder::metrics`] attaches a
//!   registry.
//! * The op-level event [`journal`] — a bounded ring buffer of spans
//!   (drop-oldest, overflow counted) exported as **Chrome trace-event
//!   JSON** (`fdbctl trace --out`, load in `chrome://tracing` /
//!   Perfetto), one track per in-flight engine lane.
//!
//! The engine ([`crate::fdb::engine`]) records **admission wait** (time
//! queued on the depth semaphore) and **service time** (inner op)
//! separately per [`crate::sim::trace::OpClass`], plus bytes and
//! outcome (ok / typed error / injected fault). `fdbctl metrics` prints
//! the registry as Prometheus-style text; `--metrics <path>` on
//! `hammer`/`opsrun`/`crash` dumps it as JSON.

pub mod instrument;
pub mod journal;
pub mod registry;

pub use instrument::{InstrumentCatalogue, InstrumentStore};
pub use journal::{Journal, SpanEvent};
pub use registry::{
    Counter, EngineMetrics, Gauge, Hist, HistogramSnapshot, MetricsRegistry, OpProbe, SlowOp,
};

use crate::fdb::FdbError;

/// Whether an error is an *injected* fault (the seeded fault harness)
/// rather than an organic backend failure — telemetry labels the two
/// outcomes separately so a chaos run's error budget reads correctly.
pub fn is_injected_fault(err: &FdbError) -> bool {
    match err {
        FdbError::Backend { backend, .. } => *backend == "fault",
        FdbError::AllReplicasFailed { last, .. } => is_injected_fault(last),
        _ => false,
    }
}

/// Whether an error is worth retrying: deadline timeouts and injected
/// faults carrying the `transient` marker
/// ([`crate::fdb::fault::FaultAction::Err`]'s `:transient` spec suffix)
/// are; everything else — permanent injected faults (fail-stop, torn
/// writes, unmarked err rules), organic backend failures, config and
/// schema errors — is not. `AllReplicasFailed` recurses into the last
/// replica's error: if the final failure was retryable, another sweep
/// over the replica set may succeed.
pub fn is_transient(err: &FdbError) -> bool {
    match err {
        FdbError::Timeout { .. } => true,
        FdbError::Backend { backend, detail } => {
            *backend == "fault" && detail.contains("transient")
        }
        FdbError::AllReplicasFailed { last, .. } => is_transient(last),
        _ => false,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn injected_fault_detection() {
        assert!(is_injected_fault(&FdbError::Backend {
            backend: "fault",
            detail: "injected".into(),
        }));
        assert!(!is_injected_fault(&FdbError::Backend {
            backend: "posix",
            detail: "enospc".into(),
        }));
        // the injected flavour survives replica-wrapper nesting
        assert!(is_injected_fault(&FdbError::AllReplicasFailed {
            op: "read",
            copies: 2,
            last: Box::new(FdbError::Backend {
                backend: "fault",
                detail: "injected".into(),
            }),
        }));
        assert!(!is_injected_fault(&FdbError::UnderspecifiedRequest));
    }

    #[test]
    fn transient_classification() {
        // deadline timeouts are always retryable
        assert!(is_transient(&FdbError::Timeout {
            class: "data-read",
            micros: 500,
        }));
        // transient-marked injected faults are retryable...
        assert!(is_transient(&FdbError::Backend {
            backend: "fault",
            detail: "injected transient Read error (op 3)".into(),
        }));
        // ...unmarked injected faults and organic failures are not
        assert!(!is_transient(&FdbError::Backend {
            backend: "fault",
            detail: "injected Read error (op 3)".into(),
        }));
        assert!(!is_transient(&FdbError::Backend {
            backend: "fault",
            detail: "instance is fail-stopped".into(),
        }));
        assert!(!is_transient(&FdbError::Backend {
            backend: "posix",
            detail: "transient-looking but organic".into(),
        }));
        // the classification survives replica-wrapper nesting
        assert!(is_transient(&FdbError::AllReplicasFailed {
            op: "read",
            copies: 3,
            last: Box::new(FdbError::Timeout {
                class: "data-read",
                micros: 100,
            }),
        }));
        assert!(!is_transient(&FdbError::UnderspecifiedRequest));
    }
}
