//! FDB administrative operations (thesis §2.7: "management command-line
//! tools"): dataset inventory and statistics. The backend-specific wipe
//! semantics live behind the [`crate::fdb::backend::Store`] /
//! [`crate::fdb::backend::Catalogue`] traits (`wipe_dataset` /
//! `deregister_dataset`), dispatched by [`Fdb::wipe`].

use crate::fdb::key::Key;
use crate::fdb::request::Request;
use crate::fdb::Fdb;

/// Summary statistics for one dataset.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct DatasetStats {
    pub fields: u64,
    pub bytes: u64,
    pub collocations: usize,
}

impl Fdb {
    /// Count indexed fields/bytes/collocations of a dataset.
    pub async fn stats(&mut self, ds: &Key) -> DatasetStats {
        let listed = self.list(ds, &Request::parse("").unwrap()).await;
        let mut collocs = std::collections::BTreeSet::new();
        let mut bytes = 0u64;
        for (id, loc) in &listed {
            bytes += loc.length();
            if let Some(c) = id.project(&self.schema.collocation) {
                collocs.insert(c.canonical());
            }
        }
        DatasetStats {
            fields: listed.len() as u64,
            bytes,
            collocations: collocs.len(),
        }
    }
}

#[cfg(test)]
mod tests {
    use crate::bench::scenario::{deploy, RedundancyOpt, SystemKind};
    use crate::fdb::schema::example_identifier;
    use crate::hw::profiles::Testbed;

    fn backends(kind: SystemKind) -> (crate::bench::scenario::Deployment, crate::fdb::Fdb) {
        let dep = deploy(Testbed::Gcp, kind, 2, 2, RedundancyOpt::None);
        let node = dep.client_nodes()[0].clone();
        let fdb = dep.fdb(&node);
        (dep, fdb)
    }

    #[test]
    fn stats_and_wipe_roundtrip_all_backends() {
        for kind in [SystemKind::Lustre, SystemKind::Daos, SystemKind::Ceph] {
            let (dep, mut fdb) = backends(kind);
            dep.sim.spawn(async move {
                for step in 1..=4u32 {
                    let id = example_identifier().with("step", step.to_string());
                    fdb.archive(&id, vec![7u8; 2048]).await.unwrap();
                }
                fdb.flush().await.expect("flush");
                fdb.close().await.expect("close");
                let ds = example_identifier()
                    .project(&fdb.schema.dataset.clone())
                    .unwrap();
                let stats = fdb.stats(&ds).await;
                assert_eq!(stats.fields, 4, "{kind:?}");
                assert_eq!(stats.bytes, 4 * 2048, "{kind:?}");
                assert!(stats.collocations >= 1, "{kind:?}");
                // wipe and verify emptiness
                assert!(fdb.wipe(&ds).await, "{kind:?} wipe");
                fdb.invalidate_preload(&ds);
                let stats = fdb.stats(&ds).await;
                assert_eq!(stats.fields, 0, "{kind:?} after wipe");
            });
            dep.sim.run();
        }
    }

    #[test]
    fn wipe_missing_dataset_is_false() {
        let (dep, mut fdb) = backends(SystemKind::Daos);
        dep.sim.spawn(async move {
            let ds = example_identifier()
                .with("date", "19990101")
                .project(&fdb.schema.dataset.clone())
                .unwrap();
            assert!(!fdb.wipe(&ds).await);
        });
        dep.sim.run();
    }
}
