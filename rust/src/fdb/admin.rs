//! FDB administrative operations (thesis §2.7: "management command-line
//! tools"): dataset inventory, statistics, and wipe. Wipe semantics per
//! backend follow the thesis' maintenance discussion — a DAOS dataset is
//! one `cont_destroy`; RADOS deletes the namespace's objects; POSIX
//! unlinks the dataset directory tree.

use crate::fdb::key::Key;
use crate::fdb::request::Request;
use crate::fdb::{CatalogueBackend, Fdb, StoreBackend};

/// Summary statistics for one dataset.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct DatasetStats {
    pub fields: u64,
    pub bytes: u64,
    pub collocations: usize,
}

impl Fdb {
    /// Count indexed fields/bytes/collocations of a dataset.
    pub async fn stats(&mut self, ds: &Key) -> DatasetStats {
        let listed = self.list(ds, &Request::parse("").unwrap()).await;
        let mut collocs = std::collections::BTreeSet::new();
        let mut bytes = 0u64;
        for (id, loc) in &listed {
            bytes += loc.length();
            if let Some(c) = id.project(&self.schema.collocation) {
                collocs.insert(c.canonical());
            }
        }
        DatasetStats {
            fields: listed.len() as u64,
            bytes,
            collocations: collocs.len(),
        }
    }

    /// Remove a dataset wholesale. Returns whether anything was removed.
    ///
    /// * DAOS: one `daos_cont_destroy` (the thesis' argument for the
    ///   container-per-dataset design) + root-KV deregistration.
    /// * Ceph/RADOS: delete every object in the dataset namespace +
    ///   deregister from the root omap.
    /// * POSIX: unlink all files in the dataset directory.
    pub async fn wipe(&mut self, ds: &Key) -> bool {
        match (&mut self.store, &mut self.catalogue) {
            (StoreBackend::Daos(store), CatalogueBackend::Daos(cat)) => {
                let removed = store.wipe_dataset(ds).await;
                cat.deregister_dataset(ds).await;
                removed
            }
            (StoreBackend::Rados(store), CatalogueBackend::Rados(cat)) => {
                let n = store.wipe_dataset(ds).await;
                cat.deregister_dataset(ds).await;
                n > 0
            }
            (StoreBackend::Posix(store), CatalogueBackend::Posix(_)) => {
                store.wipe_dataset(ds).await
            }
            _ => false,
        }
    }
}

#[cfg(test)]
mod tests {
    use crate::bench::scenario::{deploy, RedundancyOpt, SystemKind, SystemUnderTest};
    use crate::fdb::schema::example_identifier;
    use crate::fdb::setup;
    use crate::hw::profiles::Testbed;

    fn backends(kind: SystemKind) -> (crate::bench::scenario::Deployment, crate::fdb::Fdb) {
        let dep = deploy(Testbed::Gcp, kind, 2, 2, RedundancyOpt::None);
        let node = dep.client_nodes()[0].clone();
        let fdb = match &dep.system {
            SystemUnderTest::Lustre(fs) => setup::posix_fdb(&dep.sim, fs, &node, "/fdb"),
            SystemUnderTest::Daos(d) => setup::daos_fdb(&dep.sim, d, &node, "fdb"),
            SystemUnderTest::Ceph(c, pool) => setup::rados_fdb(&dep.sim, c, pool, &node),
        };
        (dep, fdb)
    }

    #[test]
    fn stats_and_wipe_roundtrip_all_backends() {
        for kind in [SystemKind::Lustre, SystemKind::Daos, SystemKind::Ceph] {
            let (dep, mut fdb) = backends(kind);
            dep.sim.spawn(async move {
                for step in 1..=4u32 {
                    let id = example_identifier().with("step", step.to_string());
                    fdb.archive(&id, vec![7u8; 2048]).await.unwrap();
                }
                fdb.flush().await;
                fdb.close().await;
                let ds = example_identifier()
                    .project(&fdb.schema.dataset.clone())
                    .unwrap();
                let stats = fdb.stats(&ds).await;
                assert_eq!(stats.fields, 4, "{kind:?}");
                assert_eq!(stats.bytes, 4 * 2048, "{kind:?}");
                assert!(stats.collocations >= 1, "{kind:?}");
                // wipe and verify emptiness
                assert!(fdb.wipe(&ds).await, "{kind:?} wipe");
                fdb.invalidate_preload(&ds);
                let stats = fdb.stats(&ds).await;
                assert_eq!(stats.fields, 0, "{kind:?} after wipe");
            });
            dep.sim.run();
        }
    }

    #[test]
    fn wipe_missing_dataset_is_false() {
        let (dep, mut fdb) = backends(SystemKind::Daos);
        dep.sim.spawn(async move {
            let ds = example_identifier()
                .with("date", "19990101")
                .project(&fdb.schema.dataset.clone())
                .unwrap();
            assert!(!fdb.wipe(&ds).await);
        });
        dep.sim.run();
    }
}
