//! POSIX client API over the simulated Lustre: open/create/write/read/
//! fsync/stat/mkdir/readdir/unlink with page-cache and DLM semantics.

use std::collections::HashMap;
use std::rc::Rc;

use super::dlm::LockMode;
use super::{Lustre, StripeSpec};
use crate::util::content::Bytes;
use crate::sim::futures::{boxed, join_all};
use crate::sim::time::{transfer_time, SimTime};
use crate::hw::node::Node;

/// File-system error surface (subset of POSIX errno space).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum FsError {
    NotFound,
    AlreadyExists,
    NotADirectory,
}

impl std::fmt::Display for FsError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "{self:?}")
    }
}
impl std::error::Error for FsError {}

/// An open file handle.
#[derive(Clone, Debug)]
pub struct Fd {
    ino: u64,
    path: String,
    append: bool,
}

impl Fd {
    pub fn ino(&self) -> u64 {
        self.ino
    }
    pub fn path(&self) -> &str {
        &self.path
    }
}

/// A mounted client; one per simulated process.
pub struct LustreClient {
    fs: Rc<Lustre>,
    node: Rc<Node>,
    pub id: u64,
    /// dirty page bytes per inode, held in this client's page cache
    dirty: HashMap<u64, u64>,
    /// accumulated virtual time spent acquiring DLM locks (incl. forced
    /// revocation flushes) — consumed by FDB profiling (Figs 4.15/4.25)
    lock_time: std::cell::Cell<crate::sim::time::SimTime>,
}

impl LustreClient {
    pub(crate) fn new(fs: Rc<Lustre>, node: Rc<Node>, id: u64) -> LustreClient {
        LustreClient {
            fs,
            node,
            id,
            dirty: HashMap::new(),
            lock_time: std::cell::Cell::new(crate::sim::time::SimTime::ZERO),
        }
    }

    /// A fresh client on the same mount and node: new client id, own
    /// page cache and DLM identity. Backs the FDB per-request I/O
    /// sessions (`fdb::backend::Store::session`) — concurrent sessions
    /// behave like additional processes of the same job.
    pub fn fork(&self) -> LustreClient {
        self.fs.client(&self.node)
    }

    /// Drain the accumulated DLM lock time (profiling helper).
    pub fn take_lock_time(&self) -> crate::sim::time::SimTime {
        let t = self.lock_time.get();
        self.lock_time
            .set(crate::sim::time::SimTime::ZERO);
        t
    }

    pub fn node(&self) -> &Rc<Node> {
        &self.node
    }

    async fn syscall(&self) {
        self.fs
            .sim
            .sleep(self.fs.config.syscall_cpu)
            .await;
    }

    fn parent_of(path: &str) -> &str {
        match path.rfind('/') {
            Some(0) => "/",
            Some(i) => &path[..i],
            None => "/",
        }
    }

    fn leaf_of(path: &str) -> &str {
        path.rsplit('/').next().unwrap_or(path)
    }

    fn shard_of(path: &str) -> u64 {
        crate::ceph::hash_name(path)
    }

    /// `mkdir`: atomic even under contention (MDS serializes).
    pub async fn mkdir(&mut self, path: &str) -> Result<(), FsError> {
        self.syscall().await;
        self.fs
            .mds_op_on(
                &self.fs.sim,
                self.fs.config.mds_costs.mkdir,
                true,
                Self::shard_of(path),
            )
            .await;
        {
            // ENOTDIR: the path or its parent already exists as a
            // regular file
            let ns = self.fs.namespace.borrow();
            if ns.contains_key(path) || ns.contains_key(Self::parent_of(path)) {
                return Err(FsError::NotADirectory);
            }
        }
        let mut dirs = self.fs.dirs.borrow_mut();
        if dirs.contains_key(path) {
            return Err(FsError::AlreadyExists);
        }
        dirs.insert(path.to_string(), Vec::new());
        Ok(())
    }

    pub async fn dir_exists(&mut self, path: &str) -> bool {
        self.syscall().await;
        self.fs
            .mds_op(&self.fs.sim, self.fs.config.mds_costs.stat, false)
            .await;
        self.fs.dirs.borrow().contains_key(path)
    }

    /// `open(O_CREAT|O_EXCL)` with an explicit striping layout.
    pub async fn create(&mut self, path: &str, stripe: StripeSpec) -> Result<Fd, FsError> {
        self.syscall().await;
        self.fs
            .mds_op_on(
                &self.fs.sim,
                self.fs.config.mds_costs.create,
                true,
                Self::shard_of(path),
            )
            .await;
        {
            let ns = self.fs.namespace.borrow();
            if ns.contains_key(path) {
                return Err(FsError::AlreadyExists);
            }
        }
        let ino = self.fs.next_ino.get();
        self.fs.next_ino.set(ino + 1);
        // allocate OSTs round-robin starting from a rotating cursor
        let nost = self.fs.osts.len();
        let count = stripe.count.min(nost).max(1);
        let first = self.fs.next_ost.get();
        self.fs.next_ost.set((first + count) % nost);
        let osts = (0..count).map(|i| (first + i) % nost).collect();
        self.fs.namespace.borrow_mut().insert(path.to_string(), ino);
        self.fs.files.borrow_mut().insert(
            ino,
            super::FileState {
                data: crate::util::content::Content::new(),
                stripe,
                osts,
            },
        );
        self.fs
            .dirs
            .borrow_mut()
            .entry(Self::parent_of(path).to_string())
            .or_default()
            .push(Self::leaf_of(path).to_string());
        Ok(Fd {
            ino,
            path: path.to_string(),
            append: true,
        })
    }

    /// `open` existing for read/write. `Ok(None)` if missing.
    pub async fn open(&mut self, path: &str) -> Result<Option<Fd>, FsError> {
        self.syscall().await;
        self.fs
            .mds_op_on(
                &self.fs.sim,
                self.fs.config.mds_costs.open,
                false,
                Self::shard_of(path),
            )
            .await;
        Ok(self
            .fs
            .namespace
            .borrow()
            .get(path)
            .map(|&ino| Fd {
                ino,
                path: path.to_string(),
                append: false,
            }))
    }

    /// `open(O_APPEND)`.
    pub async fn open_append(&mut self, path: &str) -> Result<Option<Fd>, FsError> {
        let fd = self.open(path).await?;
        Ok(fd.map(|mut f| {
            f.append = true;
            f
        }))
    }

    pub async fn stat(&mut self, path: &str) -> Option<u64> {
        self.syscall().await;
        self.fs
            .mds_op(&self.fs.sim, self.fs.config.mds_costs.stat, false)
            .await;
        let ns = self.fs.namespace.borrow();
        let ino = ns.get(path)?;
        self.fs.files.borrow().get(ino).map(|f| f.data.len())
    }

    pub async fn readdir(&mut self, path: &str) -> Result<Vec<String>, FsError> {
        self.syscall().await;
        // cost grows with entry count (getdents batches)
        let n = self
            .fs
            .dirs
            .borrow()
            .get(path)
            .map(|v| v.len())
            .ok_or(FsError::NotFound)?;
        let extra = SimTime::micros((n as u64 / 64) * 10);
        self.fs
            .mds_op(
                &self.fs.sim,
                self.fs.config.mds_costs.readdir_base + extra,
                false,
            )
            .await;
        Ok(self.fs.dirs.borrow().get(path).cloned().unwrap_or_default())
    }

    pub async fn unlink(&mut self, path: &str) -> Result<(), FsError> {
        self.syscall().await;
        self.fs
            .mds_op(&self.fs.sim, self.fs.config.mds_costs.unlink, true)
            .await;
        let ino = self
            .fs
            .namespace
            .borrow_mut()
            .remove(path)
            .ok_or(FsError::NotFound)?;
        self.fs.files.borrow_mut().remove(&ino);
        self.fs.dlm.drop_client(ino, self.id);
        if let Some(children) = self
            .fs
            .dirs
            .borrow_mut()
            .get_mut(Self::parent_of(path))
        {
            children.retain(|c| c != Self::leaf_of(path));
        }
        Ok(())
    }

    /// Acquire a lock, charging conflict round trips and displaced-writer
    /// dirty flushes to this caller (cooperative revocation model).
    async fn lock(&mut self, ino: u64, mode: LockMode) {
        let t0 = self.fs.sim.now();
        self.lock_inner(ino, mode).await;
        let dt = self.fs.sim.now() - t0;
        self.lock_time.set(self.lock_time.get() + dt);
    }

    async fn lock_inner(&mut self, ino: u64, mode: LockMode) {
        let outcome = self.fs.dlm.request(ino, self.id, mode).await;
        if outcome.cached {
            return;
        }
        // grant round trip to the lock server (resident on the OSS/MDS)
        self.fs.cluster.fabric.rpc_rtt(&self.fs.sim).await;
        if outcome.had_conflict {
            // revocation callback round trip per displaced holder
            self.fs.cluster.fabric.rpc_rtt(&self.fs.sim).await;
        }
        for w in outcome.revoked_writers {
            // force write-back of the displaced writer's dirty pages
            let dirty = self
                .fs
                .files
                .borrow()
                .get(&ino)
                .map(|_| ())
                .and_then(|_| self.take_foreign_dirty(w, ino));
            if let Some(bytes) = dirty {
                self.writeback(ino, bytes).await;
            }
        }
    }

    /// Remove another client's dirty accounting for `ino` (shared map).
    fn take_foreign_dirty(&self, client: u64, ino: u64) -> Option<u64> {
        let mut map = self.fs.foreign_dirty.borrow_mut();
        map.remove(&(client, ino)).filter(|&b| b > 0)
    }

    fn publish_dirty(&self, ino: u64, bytes: u64) {
        *self
            .fs
            .foreign_dirty
            .borrow_mut()
            .entry((self.id, ino))
            .or_insert(0) = bytes;
    }

    /// Write `buf` (append). Data lands in the client page cache (a
    /// memcpy) and the shared authoritative content immediately; media
    /// persistence happens on fsync/fdatasync or dirty-budget pressure.
    pub async fn write(&mut self, fd: &Fd, buf: &[u8]) -> Result<u64, FsError> {
        self.write_data(fd, Bytes::real(buf.to_vec())).await
    }

    /// Append a (possibly virtual) byte string — the bulk-data path.
    pub async fn write_data(&mut self, fd: &Fd, data: Bytes) -> Result<u64, FsError> {
        self.syscall().await;
        self.lock(fd.ino, LockMode::Pw).await;
        let dlen = data.len();
        // page-cache memcpy
        self.fs
            .sim
            .sleep(transfer_time(dlen, self.fs.config.memcpy_bw))
            .await;
        let offset = {
            let mut files = self.fs.files.borrow_mut();
            let f = files.get_mut(&fd.ino).ok_or(FsError::NotFound)?;
            f.data.append(data)
        };
        let d = self.dirty.entry(fd.ino).or_insert(0);
        *d += dlen;
        let now_dirty = *d;
        self.publish_dirty(fd.ino, now_dirty);
        if now_dirty > self.fs.config.dirty_budget {
            self.flush_ino(fd.ino).await;
        }
        Ok(offset)
    }

    /// Positional write of a (possibly virtual) byte string — the
    /// scrub/repair path rewriting a damaged range in place.
    pub async fn pwrite_data(&mut self, fd: &Fd, offset: u64, data: Bytes) -> Result<(), FsError> {
        self.syscall().await;
        self.lock(fd.ino, LockMode::Pw).await;
        let dlen = data.len();
        self.fs
            .sim
            .sleep(transfer_time(dlen, self.fs.config.memcpy_bw))
            .await;
        {
            let mut files = self.fs.files.borrow_mut();
            let f = files.get_mut(&fd.ino).ok_or(FsError::NotFound)?;
            f.data.write(offset, data);
        }
        let d = self.dirty.entry(fd.ino).or_insert(0);
        *d += dlen;
        let now_dirty = *d;
        self.publish_dirty(fd.ino, now_dirty);
        Ok(())
    }

    /// Positional write at an arbitrary offset (extends the file if needed).
    pub async fn pwrite(&mut self, fd: &Fd, offset: u64, buf: &[u8]) -> Result<(), FsError> {
        self.syscall().await;
        self.lock(fd.ino, LockMode::Pw).await;
        self.fs
            .sim
            .sleep(transfer_time(buf.len() as u64, self.fs.config.memcpy_bw))
            .await;
        {
            let mut files = self.fs.files.borrow_mut();
            let f = files.get_mut(&fd.ino).ok_or(FsError::NotFound)?;
            f.data.write(offset, Bytes::real(buf.to_vec()));
        }
        let d = self.dirty.entry(fd.ino).or_insert(0);
        *d += buf.len() as u64;
        let now_dirty = *d;
        self.publish_dirty(fd.ino, now_dirty);
        Ok(())
    }

    /// Transfer `bytes` of (this or a displaced client's) dirty pages to
    /// the file's OSTs, striped and concurrent.
    async fn writeback(&self, ino: u64, bytes: u64) {
        let (osts, stripe) = {
            let files = self.fs.files.borrow();
            let Some(f) = files.get(&ino) else { return };
            (f.osts.clone(), f.stripe)
        };
        let per_ost = bytes / osts.len() as u64;
        let rem = bytes % osts.len() as u64;
        let sim = self.fs.sim.clone();
        let futs = osts
            .iter()
            .enumerate()
            .map(|(i, &oi)| {
                let oss = self.fs.osts[oi].oss_node.clone();
                let fabric = self.fs.cluster.fabric.clone();
                let me = self.node.clone();
                let sim = sim.clone();
                let oss_cpu = self.fs.config.oss_op_cpu;
                let chunk = per_ost + if (i as u64) < rem { 1 } else { 0 };
                boxed(async move {
                    if chunk == 0 {
                        return;
                    }
                    // per-RPC ops of stripe_size each
                    let nops = chunk.div_ceil(stripe.size).max(1);
                    fabric.xfer(&sim, &me.nic, &oss.nic, chunk).await;
                    oss.cpu_serve(&sim, SimTime::nanos(oss_cpu.as_nanos() * nops))
                        .await;
                    oss.dev().write(&sim, chunk).await;
                })
            })
            .collect();
        join_all(futs).await;
    }

    async fn flush_ino(&mut self, ino: u64) {
        let bytes = self.dirty.remove(&ino).unwrap_or(0);
        self.publish_dirty(ino, 0);
        if bytes > 0 {
            self.writeback(ino, bytes).await;
        }
    }

    /// `fdatasync`: persist this client's dirty pages for the file.
    pub async fn fdatasync(&mut self, fd: &Fd) -> Result<(), FsError> {
        self.syscall().await;
        self.flush_ino(fd.ino).await;
        Ok(())
    }

    /// `fsync` — same data path; metadata journal already on MDS.
    pub async fn fsync(&mut self, fd: &Fd) -> Result<(), FsError> {
        self.fdatasync(fd).await
    }

    /// Read `len` bytes at `offset`. Takes a PR lock (revoking and
    /// flushing any conflicting writer), then streams from the OSTs.
    pub async fn read(&mut self, fd: &Fd, offset: u64, len: u64) -> Result<Bytes, FsError> {
        self.syscall().await;
        self.lock(fd.ino, LockMode::Pr).await;
        let (osts, stripe, data) = {
            let files = self.fs.files.borrow();
            let f = files.get(&fd.ino).ok_or(FsError::NotFound)?;
            let end = (offset + len).min(f.data.len());
            let start = offset.min(end);
            (f.osts.clone(), f.stripe, f.data.read(start, end - start))
        };
        let bytes = data.len();
        if bytes > 0 {
            // concurrent per-OST streams back to the client
            let touched = osts.len().min(bytes.div_ceil(stripe.size).max(1) as usize);
            let per_ost = bytes / touched as u64;
            let sim = self.fs.sim.clone();
            let futs = osts
                .iter()
                .take(touched)
                .map(|&oi| {
                    let oss = self.fs.osts[oi].oss_node.clone();
                    let fabric = self.fs.cluster.fabric.clone();
                    let me = self.node.clone();
                    let sim = sim.clone();
                    let oss_cpu = self.fs.config.oss_op_cpu;
                    boxed(async move {
                        let nops = per_ost.div_ceil(stripe.size).max(1);
                        oss.cpu_serve(&sim, SimTime::nanos(oss_cpu.as_nanos() * nops))
                            .await;
                        oss.dev().read(&sim, per_ost).await;
                        fabric.xfer(&sim, &oss.nic, &me.nic, per_ost).await;
                    })
                })
                .collect();
            join_all(futs).await;
        }
        Ok(data)
    }

    /// Read a whole file (stat + read).
    pub async fn read_all(&mut self, path: &str) -> Result<Bytes, FsError> {
        let size = self.stat(path).await.ok_or(FsError::NotFound)?;
        let fd = self.open(path).await?.ok_or(FsError::NotFound)?;
        self.read(&fd, 0, size).await
    }

    /// Current size without an MDS round trip (used internally by FDB).
    pub fn cached_size(&self, fd: &Fd) -> u64 {
        self.fs
            .files
            .borrow()
            .get(&fd.ino)
            .map(|f| f.data.len())
            .unwrap_or(0)
    }
}
