//! Lustre Distributed Lock Manager model.
//!
//! Whole-file extent locks with **client lock caching**: once a client is
//! granted PW (protected write) or PR (protected read) on a file, it keeps
//! the grant until another client's conflicting request triggers a
//! revocation callback. Revocation of a PW grant forces the holder's dirty
//! pages for that file to be written back before the new grant is issued —
//! the requester waits for that flush, which is the mechanism behind the
//! write+read contention collapse the thesis measures on Lustre.
//!
//! Cooperative model: the *requesting* task performs (and is charged) the
//! revocation round trips and the displaced dirty write-back; the previous
//! holder simply finds its cached grant gone and re-requests on next use.

use std::cell::{Cell, RefCell};
use std::collections::{HashMap, HashSet};
use std::rc::Rc;

use crate::sim::resource::{mutex, Resource};

/// Lock compatibility modes (subset of Lustre's ibits/extent modes).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum LockMode {
    /// protected read — shared
    Pr,
    /// protected write — exclusive
    Pw,
}

#[derive(Default)]
struct FileLockState {
    /// clients holding cached PR grants
    readers: HashSet<u64>,
    /// client holding the cached PW grant, if any
    writer: Option<u64>,
}

/// Aggregate counters for reports and tests.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct DlmStats {
    pub grants: u64,
    pub conflicts: u64,
    pub pw_revocations: u64,
    pub pr_revocations: u64,
}

pub(crate) struct Dlm {
    locks: RefCell<HashMap<u64, FileLockState>>,
    /// one queue per file serializes conflicting grant processing
    queues: RefCell<HashMap<u64, Rc<Resource>>>,
    grants: Cell<u64>,
    conflicts: Cell<u64>,
    pw_revocations: Cell<u64>,
    pr_revocations: Cell<u64>,
}

/// Outcome the POSIX layer must act upon after a grant.
pub(crate) struct GrantOutcome {
    /// client ids whose PW grant was revoked (their dirty pages must be
    /// flushed by the caller before proceeding)
    pub revoked_writers: Vec<u64>,
    /// whether any conflict occurred (extra round trips to charge)
    pub had_conflict: bool,
    /// whether this client already held a compatible cached grant
    pub cached: bool,
}

impl Dlm {
    pub fn new() -> Dlm {
        Dlm {
            locks: RefCell::new(HashMap::new()),
            queues: RefCell::new(HashMap::new()),
            grants: Cell::new(0),
            conflicts: Cell::new(0),
            pw_revocations: Cell::new(0),
            pr_revocations: Cell::new(0),
        }
    }

    pub fn stats(&self) -> DlmStats {
        DlmStats {
            grants: self.grants.get(),
            conflicts: self.conflicts.get(),
            pw_revocations: self.pw_revocations.get(),
            pr_revocations: self.pr_revocations.get(),
        }
    }

    fn queue_for(&self, ino: u64) -> Rc<Resource> {
        self.queues
            .borrow_mut()
            .entry(ino)
            .or_insert_with(|| mutex(format!("dlm/{ino}")))
            .clone()
    }

    /// Request a grant for `client` on file `ino`. Returns which cached
    /// writer grants were displaced (caller flushes their dirty pages) and
    /// whether a conflict happened. Grant bookkeeping is immediate; the
    /// caller charges the time costs.
    pub async fn request(&self, ino: u64, client: u64, mode: LockMode) -> GrantOutcome {
        // serialize conflicting decisions per file
        let q = self.queue_for(ino);
        q.acquire().await;
        let mut locks = self.locks.borrow_mut();
        let st = locks.entry(ino).or_default();

        // already cached and compatible?
        let cached = match mode {
            LockMode::Pw => st.writer == Some(client),
            LockMode::Pr => {
                st.readers.contains(&client) && st.writer.is_none()
                    || st.writer == Some(client)
            }
        };
        if cached {
            q.release();
            return GrantOutcome {
                revoked_writers: vec![],
                had_conflict: false,
                cached: true,
            };
        }

        let mut revoked_writers = Vec::new();
        let mut had_conflict = false;
        match mode {
            LockMode::Pw => {
                if let Some(w) = st.writer.take() {
                    if w != client {
                        revoked_writers.push(w);
                        self.pw_revocations.set(self.pw_revocations.get() + 1);
                        had_conflict = true;
                    }
                }
                if !st.readers.is_empty() {
                    self.pr_revocations
                        .set(self.pr_revocations.get() + st.readers.len() as u64);
                    st.readers.clear();
                    had_conflict = true;
                }
                st.writer = Some(client);
            }
            LockMode::Pr => {
                if let Some(w) = st.writer.take() {
                    if w != client {
                        revoked_writers.push(w);
                        self.pw_revocations.set(self.pw_revocations.get() + 1);
                        had_conflict = true;
                    }
                }
                st.readers.insert(client);
            }
        }
        self.grants.set(self.grants.get() + 1);
        if had_conflict {
            self.conflicts.set(self.conflicts.get() + 1);
        }
        drop(locks);
        q.release();
        GrantOutcome {
            revoked_writers,
            had_conflict,
            cached: false,
        }
    }

    /// Drop any cached grant (e.g. on file close/unlink).
    pub fn drop_client(&self, ino: u64, client: u64) {
        if let Some(st) = self.locks.borrow_mut().get_mut(&ino) {
            if st.writer == Some(client) {
                st.writer = None;
            }
            st.readers.remove(&client);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::exec::Sim;

    fn run_one<F: std::future::Future<Output = ()> + 'static>(f: F) {
        let sim = Sim::new();
        sim.spawn(f);
        sim.run();
    }

    #[test]
    fn first_pw_grant_is_clean() {
        let dlm = Rc::new(Dlm::new());
        let d = dlm.clone();
        run_one(async move {
            let g = d.request(1, 10, LockMode::Pw).await;
            assert!(!g.had_conflict);
            assert!(!g.cached);
            assert!(g.revoked_writers.is_empty());
        });
        assert_eq!(dlm.stats().grants, 1);
    }

    #[test]
    fn cached_pw_regrant_is_free() {
        let dlm = Rc::new(Dlm::new());
        let d = dlm.clone();
        run_one(async move {
            d.request(1, 10, LockMode::Pw).await;
            let g = d.request(1, 10, LockMode::Pw).await;
            assert!(g.cached);
        });
        assert_eq!(dlm.stats().grants, 1);
    }

    #[test]
    fn reader_revokes_writer() {
        let dlm = Rc::new(Dlm::new());
        let d = dlm.clone();
        run_one(async move {
            d.request(1, 10, LockMode::Pw).await;
            let g = d.request(1, 20, LockMode::Pr).await;
            assert!(g.had_conflict);
            assert_eq!(g.revoked_writers, vec![10]);
        });
        let s = dlm.stats();
        assert_eq!(s.pw_revocations, 1);
        assert_eq!(s.conflicts, 1);
    }

    #[test]
    fn writer_after_reader_conflicts_without_flush() {
        let dlm = Rc::new(Dlm::new());
        let d = dlm.clone();
        run_one(async move {
            d.request(1, 20, LockMode::Pr).await;
            let g = d.request(1, 10, LockMode::Pw).await;
            assert!(g.had_conflict);
            assert!(g.revoked_writers.is_empty()); // readers have no dirty pages
        });
        assert_eq!(dlm.stats().pr_revocations, 1);
    }

    #[test]
    fn concurrent_readers_share() {
        let dlm = Rc::new(Dlm::new());
        let d = dlm.clone();
        run_one(async move {
            d.request(1, 1, LockMode::Pr).await;
            let g = d.request(1, 2, LockMode::Pr).await;
            assert!(!g.had_conflict);
        });
        assert_eq!(dlm.stats().conflicts, 0);
    }

    #[test]
    fn ping_pong_counts_both_revocations() {
        let dlm = Rc::new(Dlm::new());
        let d = dlm.clone();
        run_one(async move {
            for _ in 0..5 {
                d.request(1, 1, LockMode::Pw).await;
                d.request(1, 2, LockMode::Pr).await;
            }
        });
        let s = dlm.stats();
        assert_eq!(s.pw_revocations, 5);
        assert!(s.pr_revocations >= 4);
    }
}
