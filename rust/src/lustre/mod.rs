//! Simulated Lustre distributed file system (thesis §2.2.1).
//!
//! Faithful to the architectural mechanisms that drive the paper's
//! results:
//!
//! * **Centralized metadata** — every namespace op (create/open/stat/
//!   mkdir/unlink) is an RPC to the single MDS node, served by a bounded
//!   thread pool and journaled on the MDT device. This is the scaling
//!   bottleneck object stores avoid via algorithmic placement.
//! * **Distributed Lock Manager** — whole-file extent locks with client
//!   lock caching and revocation callbacks. Write+read contention causes
//!   lock ping-pong plus forced dirty-page flushes, reproducing the
//!   thesis' contention penalty (Figs 4.13/4.15/4.22/4.25).
//! * **Client page cache** — writes buffer in client memory (a memcpy)
//!   and persist on fsync/fdatasync or dirty-budget pressure; this is why
//!   Lustre wins at small scale and why flush() is expensive.
//! * **Striping** — files split across OSTs in `stripe_size` chunks,
//!   transfers to distinct OSTs proceed concurrently.
//!
//! File *content* is real bytes held in shared state (POSIX strong
//! consistency: reads always observe prior writes); only time is
//! simulated.

mod dlm;
mod posix;

pub use dlm::{DlmStats, LockMode};
pub use posix::{Fd, FsError, LustreClient};

use std::cell::{Cell, RefCell};
use std::collections::HashMap;
use std::rc::Rc;

use crate::hw::cluster::Cluster;
use crate::hw::node::Node;
use crate::sim::exec::Sim;
use crate::sim::resource::Resource;
use crate::sim::time::SimTime;

/// Striping layout for a file (Lustre `lfs setstripe`).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct StripeSpec {
    /// number of OSTs the file is spread over
    pub count: usize,
    /// bytes per stripe chunk
    pub size: u64,
}

impl StripeSpec {
    /// Lustre default: a single OST, 1 MiB stripes.
    pub fn default_layout() -> StripeSpec {
        StripeSpec {
            count: 1,
            size: 1 << 20,
        }
    }

    /// The FDB's data-file layout: 8 OSTs × 8 MiB (thesis §2.7.2).
    pub fn fdb_data() -> StripeSpec {
        StripeSpec {
            count: 8,
            size: 8 << 20,
        }
    }
}

/// Per-file authoritative state.
pub(crate) struct FileState {
    pub data: crate::util::content::Content,
    pub stripe: StripeSpec,
    /// OST indices this file's stripes live on (round-robin)
    pub osts: Vec<usize>,
}

/// MDS service-time calibration (per metadata op class).
#[derive(Clone, Copy, Debug)]
pub struct MdsCosts {
    pub create: SimTime,
    pub open: SimTime,
    pub stat: SimTime,
    pub mkdir: SimTime,
    pub unlink: SimTime,
    pub readdir_base: SimTime,
}

impl Default for MdsCosts {
    fn default() -> Self {
        MdsCosts {
            create: SimTime::micros(120),
            open: SimTime::micros(40),
            stat: SimTime::micros(30),
            mkdir: SimTime::micros(100),
            unlink: SimTime::micros(80),
            readdir_base: SimTime::micros(50),
        }
    }
}

#[derive(Clone, Debug)]
pub struct LustreConfig {
    /// OSTs per OSS node (each OST shares the node device)
    pub osts_per_oss: usize,
    /// DNE: number of MDS service instances the metadata workload is
    /// balanced over (DNE2-style striped directories; thesis §2.2.1)
    pub mds_count: usize,
    /// MDS service thread pool size (per MDS)
    pub mds_threads: usize,
    pub mds_costs: MdsCosts,
    /// per-bulk-op OSS server CPU cost (kernel + ldiskfs path)
    pub oss_op_cpu: SimTime,
    /// per-syscall client kernel overhead
    pub syscall_cpu: SimTime,
    /// client page-cache memcpy bandwidth (bytes/s)
    pub memcpy_bw: f64,
    /// per-(client,file) dirty budget before forced writeback
    pub dirty_budget: u64,
    pub default_stripe: StripeSpec,
}

impl Default for LustreConfig {
    fn default() -> Self {
        LustreConfig {
            osts_per_oss: 1,
            mds_count: 1,
            mds_threads: 16,
            mds_costs: MdsCosts::default(),
            oss_op_cpu: SimTime::micros(20),
            syscall_cpu: SimTime::micros(3),
            memcpy_bw: 9.0 * (1u64 << 30) as f64,
            dirty_budget: 256 << 20,
            default_stripe: StripeSpec::default_layout(),
        }
    }
}

/// One OST: served by an OSS node (sharing that node's device + NIC).
pub(crate) struct Ost {
    pub oss_node: Rc<Node>,
}

/// The deployed file system.
pub struct Lustre {
    pub sim: Sim,
    pub cluster: Rc<Cluster>,
    pub config: LustreConfig,
    pub(crate) mds_node: Rc<Node>,
    /// one bounded service pool per DNE MDS instance
    pub(crate) mds_pools: Vec<Rc<Resource>>,
    pub(crate) osts: Vec<Ost>,
    pub(crate) namespace: RefCell<HashMap<String, u64>>,
    pub(crate) dirs: RefCell<HashMap<String, Vec<String>>>,
    pub(crate) files: RefCell<HashMap<u64, FileState>>,
    pub(crate) next_ino: Cell<u64>,
    pub(crate) next_ost: Cell<usize>,
    pub(crate) dlm: dlm::Dlm,
    pub(crate) next_client: Cell<u64>,
    /// dirty-byte accounting visible across clients, keyed by
    /// (client id, inode) — needed for cooperative lock revocation.
    pub(crate) foreign_dirty: RefCell<HashMap<(u64, u64), u64>>,
}

impl Lustre {
    /// Deploy over a cluster: storage nodes become OSSs; the metadata node
    /// (or the first storage node if none) hosts the MDS.
    pub fn deploy(sim: &Sim, cluster: &Rc<Cluster>, config: LustreConfig) -> Rc<Lustre> {
        let mds_node = cluster
            .metadata_nodes()
            .next()
            .or_else(|| cluster.storage_nodes().next())
            .expect("lustre needs at least one storage or metadata node")
            .clone();
        let mut osts = Vec::new();
        for oss in cluster.storage_nodes() {
            for _ in 0..config.osts_per_oss {
                osts.push(Ost {
                    oss_node: oss.clone(),
                });
            }
        }
        assert!(!osts.is_empty(), "lustre needs at least one OST");
        let mds_pools = (0..config.mds_count.max(1))
            .map(|i| Resource::new(format!("mds{i}/threads"), config.mds_threads))
            .collect();
        Rc::new(Lustre {
            sim: sim.clone(),
            cluster: cluster.clone(),
            config,
            mds_node,
            mds_pools,
            osts,
            namespace: RefCell::new(HashMap::new()),
            dirs: RefCell::new(HashMap::new()),
            files: RefCell::new(HashMap::new()),
            next_ino: Cell::new(1),
            next_ost: Cell::new(0),
            dlm: dlm::Dlm::new(),
            next_client: Cell::new(0),
            foreign_dirty: RefCell::new(HashMap::new()),
        })
    }

    /// Create a client mounted from `node`. One per simulated process.
    pub fn client(self: &Rc<Self>, node: &Rc<Node>) -> LustreClient {
        let id = self.next_client.get();
        self.next_client.set(id + 1);
        LustreClient::new(self.clone(), node.clone(), id)
    }

    /// Aggregate DLM statistics (revocations, conflicts) for reporting.
    pub fn dlm_stats(&self) -> DlmStats {
        self.dlm.stats()
    }

    /// Number of OSTs deployed.
    pub fn ost_count(&self) -> usize {
        self.osts.len()
    }

    /// Charge an MDS metadata op: client→MDS round trip + bounded service
    /// threads + MDT journal write for mutating ops. With DNE the
    /// workload balances over `mds_count` service instances by a path
    /// hash (DNE2 striped-directory behaviour).
    pub(crate) async fn mds_op(&self, sim: &Sim, cost: SimTime, journal: bool) {
        self.mds_op_on(sim, cost, journal, 0).await;
    }

    pub(crate) async fn mds_op_on(&self, sim: &Sim, cost: SimTime, journal: bool, shard: u64) {
        let pool = &self.mds_pools[(shard as usize) % self.mds_pools.len()];
        self.cluster.fabric.msg(sim).await;
        pool.acquire().await;
        self.mds_node.cpu_serve(sim, cost).await;
        if journal {
            self.mds_node.dev().write(sim, 4096).await;
        }
        pool.release();
        self.cluster.fabric.msg(sim).await;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::hw::profiles::{build_cluster, Testbed};

    fn small_fs() -> (Sim, Rc<Lustre>, Rc<Cluster>) {
        let sim = Sim::new();
        let cluster = Rc::new(build_cluster(Testbed::NextGenIo, 2, 2, true, true));
        let fs = Lustre::deploy(&sim, &cluster, LustreConfig::default());
        (sim, fs, cluster)
    }

    #[test]
    fn deploy_assigns_osts_and_mds() {
        let (_sim, fs, _c) = small_fs();
        assert_eq!(fs.ost_count(), 2);
        assert_eq!(fs.mds_node.role, crate::hw::node::NodeRole::Metadata);
    }

    #[test]
    fn write_read_roundtrip_cross_client() {
        let (sim, fs, cluster) = small_fs();
        let client_node = cluster.client_nodes().next().unwrap().clone();
        let fs2 = fs.clone();
        sim.spawn(async move {
            let mut cli = fs2.client(&client_node);
            cli.mkdir("/data").await.unwrap();
            let fd = cli
                .create("/data/f1", StripeSpec::fdb_data())
                .await
                .unwrap();
            cli.write(&fd, b"hello lustre").await.unwrap();
            cli.fdatasync(&fd).await.unwrap();
            let back = cli.read(&fd, 0, 12).await.unwrap().to_vec();
            assert_eq!(&back, b"hello lustre");
            // cross-client visibility
            let reader_node = fs2.cluster.client_nodes().nth(1).unwrap().clone();
            let mut rdr = fs2.client(&reader_node);
            let fd2 = rdr.open("/data/f1").await.unwrap().unwrap();
            let got = rdr.read(&fd2, 6, 6).await.unwrap().to_vec();
            assert_eq!(&got, b"lustre");
        });
        sim.run();
    }

    #[test]
    fn mkdir_reports_already_exists() {
        let (sim, fs, cluster) = small_fs();
        let node = cluster.client_nodes().next().unwrap().clone();
        sim.spawn(async move {
            let mut cli = fs.client(&node);
            cli.mkdir("/d").await.unwrap();
            assert!(matches!(
                cli.mkdir("/d").await,
                Err(FsError::AlreadyExists)
            ));
        });
        sim.run();
    }

    #[test]
    fn mkdir_under_a_file_is_not_a_directory() {
        let (sim, fs, cluster) = small_fs();
        let node = cluster.client_nodes().next().unwrap().clone();
        sim.spawn(async move {
            let mut cli = fs.client(&node);
            cli.create("/plainfile", StripeSpec::default_layout())
                .await
                .unwrap();
            // ENOTDIR: both the path itself and a child path of a file
            assert!(matches!(
                cli.mkdir("/plainfile").await,
                Err(FsError::NotADirectory)
            ));
            assert!(matches!(
                cli.mkdir("/plainfile/sub").await,
                Err(FsError::NotADirectory)
            ));
        });
        sim.run();
    }

    #[test]
    fn stat_missing_file() {
        let (sim, fs, cluster) = small_fs();
        let node = cluster.client_nodes().next().unwrap().clone();
        sim.spawn(async move {
            let mut cli = fs.client(&node);
            assert!(cli.stat("/nope").await.is_none());
        });
        sim.run();
    }

    #[test]
    fn append_mode_appends_atomically() {
        let (sim, fs, cluster) = small_fs();
        let node = cluster.client_nodes().next().unwrap().clone();
        sim.spawn(async move {
            let mut a = fs.client(&node);
            a.mkdir("/d").await.unwrap();
            let fd = a
                .create("/d/toc", StripeSpec::default_layout())
                .await
                .unwrap();
            a.write(&fd, b"AAAA").await.unwrap();
            a.fdatasync(&fd).await.unwrap();
            let fd2 = a.open_append("/d/toc").await.unwrap().unwrap();
            a.write(&fd2, b"BBBB").await.unwrap();
            a.fdatasync(&fd2).await.unwrap();
            let all = a.read_all("/d/toc").await.unwrap().to_vec();
            assert_eq!(&all, b"AAAABBBB");
        });
        sim.run();
    }

    #[test]
    fn striped_file_lands_on_multiple_osts() {
        let (sim, fs, cluster) = small_fs();
        let node = cluster.client_nodes().next().unwrap().clone();
        let fs2 = fs.clone();
        sim.spawn(async move {
            let mut cli = fs2.client(&node);
            cli.mkdir("/d").await.unwrap();
            let fd = cli
                .create(
                    "/d/wide",
                    StripeSpec {
                        count: 2,
                        size: 1 << 20,
                    },
                )
                .await
                .unwrap();
            cli.write(&fd, &vec![7u8; 4 << 20]).await.unwrap();
            cli.fdatasync(&fd).await.unwrap();
            let files = fs2.files.borrow();
            let f = files.get(&fd.ino()).unwrap();
            assert_eq!(f.osts.len(), 2);
            assert_ne!(f.osts[0], f.osts[1]);
        });
        sim.run();
    }

    #[test]
    fn readdir_lists_children() {
        let (sim, fs, cluster) = small_fs();
        let node = cluster.client_nodes().next().unwrap().clone();
        sim.spawn(async move {
            let mut cli = fs.client(&node);
            cli.mkdir("/root").await.unwrap();
            for i in 0..3 {
                cli.create(&format!("/root/f{i}"), StripeSpec::default_layout())
                    .await
                    .unwrap();
            }
            let mut names = cli.readdir("/root").await.unwrap();
            names.sort();
            assert_eq!(names, vec!["f0", "f1", "f2"]);
        });
        sim.run();
    }

    #[test]
    fn unlink_removes_file() {
        let (sim, fs, cluster) = small_fs();
        let node = cluster.client_nodes().next().unwrap().clone();
        sim.spawn(async move {
            let mut cli = fs.client(&node);
            cli.mkdir("/d").await.unwrap();
            cli.create("/d/x", StripeSpec::default_layout())
                .await
                .unwrap();
            cli.unlink("/d/x").await.unwrap();
            assert!(cli.stat("/d/x").await.is_none());
        });
        sim.run();
    }
}
