//! librados client: object write/read with primary-copy
//! replication/EC, synchronous and asynchronous (aio) variants.

use std::cell::RefCell;
use std::rc::Rc;

use super::{Ceph, CephPool, RadosObj};
use crate::hw::node::Node;
use crate::sim::futures::{boxed, join_all};
use crate::sim::time::SimTime;
use crate::util::content::Bytes;

/// RADOS error surface.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum RadosError {
    NoSuchPool,
    NoSuchObject,
    ObjectTooLarge,
}

impl std::fmt::Display for RadosError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "{self:?}")
    }
}
impl std::error::Error for RadosError {}

/// An in-flight asynchronous op (rados_aio_*): the *data may not be
/// durable yet*; `aio_wait_for_complete` (via `flush_pending`) makes it
/// so. The thesis found an FDB configuration relying on aio + flush did
/// NOT meet the consistency requirements (Fig 3.5, patterned columns) —
/// we model that: aio writes become *visible* only once flushed, and a
/// configurable visibility lag mimics the observed late-visibility bug.
pub(crate) struct PendingWrite {
    pub pool: Rc<CephPool>,
    pub ns: String,
    pub name: String,
    pub data: Bytes,
}

/// A librados client handle.
pub struct RadosClient {
    pub(crate) sys: Rc<Ceph>,
    pub(crate) node: Rc<Node>,
    /// process-unique client instance id (like host+pid in naming)
    pub(crate) id: u64,
    /// OSDMap fetched from the monitor on first use
    map_fetched: RefCell<bool>,
    pending: RefCell<Vec<PendingWrite>>,
    /// emulate the observed aio visibility bug (thesis Fig 3.5 cfg 6)
    pub aio_visibility_bug: bool,
}

impl Ceph {
    pub fn client(self: &Rc<Self>, node: &Rc<Node>) -> RadosClient {
        let id = self.next_client.get();
        self.next_client.set(id + 1);
        RadosClient {
            sys: self.clone(),
            node: node.clone(),
            id,
            map_fetched: RefCell::new(false),
            pending: RefCell::new(Vec::new()),
            aio_visibility_bug: false,
        }
    }
}

impl RadosClient {
    /// A fresh librados client instance on the same cluster and node
    /// (own id for object naming, own aio queue) — backs the FDB
    /// per-request I/O sessions.
    pub fn fork(&self) -> RadosClient {
        let mut c = self.sys.client(&self.node);
        c.aio_visibility_bug = self.aio_visibility_bug;
        c
    }

    pub fn pool(&self, name: &str) -> Result<Rc<CephPool>, RadosError> {
        self.sys
            .pools
            .borrow()
            .get(name)
            .cloned()
            .ok_or(RadosError::NoSuchPool)
    }

    /// First interaction fetches the OSDMap from a monitor.
    pub(crate) async fn ensure_map(&self) {
        if *self.map_fetched.borrow() {
            return;
        }
        let sim = &self.sys.sim;
        self.sys.tcp.rpc_rtt(sim).await;
        self.sys
            .mon_node
            .cpu_serve(sim, self.sys.config.costs.mon_fetch)
            .await;
        *self.map_fetched.borrow_mut() = true;
    }

    fn osd_service(&self) -> SimTime {
        SimTime::from_secs_f64(
            self.sys.config.costs.osd_op.as_secs_f64() * self.sys.pg_penalty(),
        )
    }

    /// Primary-copy write data path: client → primary (TCP), primary
    /// persists, fans out to the remaining OSDs, acks after all durable.
    pub(crate) async fn write_path(&self, pool: &Rc<CephPool>, name: &str, bytes: u64) {
        self.sys.ops.set(self.sys.ops.get() + 1);
        let sim = self.sys.sim.clone();
        sim.sleep(self.sys.config.costs.client_op).await;
        let osds = self.sys.osds_for(pool, name);
        let primary = &self.sys.osds[osds[0]];
        self.sys
            .tcp
            .xfer(&sim, &self.node.nic, &primary.node.nic, bytes)
            .await;
        primary.node.cpu_serve(&sim, self.osd_service()).await;
        match pool.redundancy {
            super::Redundancy::None => {
                primary.node.dev().write(&sim, bytes).await;
            }
            super::Redundancy::Replica(_) => {
                // primary persists and fans out concurrently
                let futs = osds
                    .iter()
                    .enumerate()
                    .map(|(i, &oi)| {
                        let osd = &self.sys.osds[oi];
                        let primary_node = primary.node.clone();
                        let sim = sim.clone();
                        let tcp = self.sys.tcp.clone();
                        let svc = self.osd_service();
                        boxed(async move {
                            if i > 0 {
                                tcp.xfer(&sim, &primary_node.nic, &osd.node.nic, bytes).await;
                                osd.node.cpu_serve(&sim, svc).await;
                            }
                            osd.node.dev().write(&sim, bytes).await;
                        })
                    })
                    .collect();
                join_all(futs).await;
            }
            super::Redundancy::Erasure(k, _m) => {
                let chunk = bytes.div_ceil(k as u64);
                let futs = osds
                    .iter()
                    .enumerate()
                    .map(|(i, &oi)| {
                        let osd = &self.sys.osds[oi];
                        let primary_node = primary.node.clone();
                        let sim = sim.clone();
                        let tcp = self.sys.tcp.clone();
                        let svc = self.osd_service();
                        boxed(async move {
                            if i > 0 {
                                tcp.xfer(&sim, &primary_node.nic, &osd.node.nic, chunk).await;
                                osd.node.cpu_serve(&sim, svc).await;
                            }
                            osd.node.dev().write(&sim, chunk).await;
                        })
                    })
                    .collect();
                join_all(futs).await;
            }
        }
        // ack
        self.sys.tcp.msg(&sim).await;
    }

    /// Read path. EC pools fetch the FULL object extent even for partial
    /// range reads (thesis §2.5 feature table).
    pub(crate) async fn read_path(&self, pool: &Rc<CephPool>, name: &str, bytes: u64, full: u64) {
        self.sys.ops.set(self.sys.ops.get() + 1);
        let sim = self.sys.sim.clone();
        sim.sleep(self.sys.config.costs.client_op).await;
        let osds = self.sys.osds_for(pool, name);
        let primary = &self.sys.osds[osds[0]];
        self.sys.tcp.msg(&sim).await;
        primary.node.cpu_serve(&sim, self.osd_service()).await;
        match pool.redundancy {
            super::Redundancy::Erasure(k, _m) => {
                let chunk = full.div_ceil(k as u64);
                let futs = osds[..k.min(osds.len())]
                    .iter()
                    .map(|&oi| {
                        let osd = &self.sys.osds[oi];
                        let primary_node = primary.node.clone();
                        let sim = sim.clone();
                        let tcp = self.sys.tcp.clone();
                        boxed(async move {
                            osd.node.dev().read(&sim, chunk).await;
                            if !Rc::ptr_eq(&osd.node, &primary_node) {
                                tcp.xfer(&sim, &osd.node.nic, &primary_node.nic, chunk).await;
                            }
                        })
                    })
                    .collect();
                join_all(futs).await;
                self.sys
                    .tcp
                    .xfer(&sim, &primary.node.nic, &self.node.nic, full)
                    .await;
            }
            _ => {
                primary.node.dev().read(&sim, bytes).await;
                self.sys
                    .tcp
                    .xfer(&sim, &primary.node.nic, &self.node.nic, bytes)
                    .await;
            }
        }
    }

    /// `rados_write_full`: create/replace an object, durable on return.
    pub async fn write_full(
        &self,
        pool: &Rc<CephPool>,
        ns: &str,
        name: &str,
        data: &[u8],
    ) -> Result<(), RadosError> {
        self.write_full_data(pool, ns, name, Bytes::real(data.to_vec()))
            .await
    }

    /// `rados_write_full` of a (possibly virtual) byte string.
    pub async fn write_full_data(
        &self,
        pool: &Rc<CephPool>,
        ns: &str,
        name: &str,
        data: Bytes,
    ) -> Result<(), RadosError> {
        if data.len() > self.sys.config.max_object_size {
            return Err(RadosError::ObjectTooLarge);
        }
        self.ensure_map().await;
        self.write_path(pool, name, data.len()).await;
        let mut objs = pool.objects.borrow_mut();
        let obj = objs
            .entry((ns.to_string(), name.to_string()))
            .or_default();
        obj.data = crate::util::content::Content::new();
        obj.data.write(0, data);
        Ok(())
    }

    /// `rados_write` at an offset (extends as needed).
    pub async fn write_at(
        &self,
        pool: &Rc<CephPool>,
        ns: &str,
        name: &str,
        offset: u64,
        data: Bytes,
    ) -> Result<(), RadosError> {
        let end = offset + data.len();
        if end > self.sys.config.max_object_size {
            return Err(RadosError::ObjectTooLarge);
        }
        self.ensure_map().await;
        self.write_path(pool, name, data.len()).await;
        let mut objs = pool.objects.borrow_mut();
        let obj = objs
            .entry((ns.to_string(), name.to_string()))
            .or_default();
        obj.data.write(offset, data);
        Ok(())
    }

    /// `rados_aio_write_full`: returns immediately after buffering; the
    /// data is neither durable nor (with the visibility bug) readable
    /// until `flush_pending`. Costs only the client-side submit.
    pub async fn aio_write_full(
        &self,
        pool: &Rc<CephPool>,
        ns: &str,
        name: &str,
        data: impl Into<Bytes>,
    ) -> Result<(), RadosError> {
        let data: Bytes = data.into();
        if data.len() > self.sys.config.max_object_size {
            return Err(RadosError::ObjectTooLarge);
        }
        self.sys
            .sim
            .sleep(self.sys.config.costs.client_op)
            .await;
        if !self.aio_visibility_bug {
            // content visible immediately (but not durable)
            let mut objs = pool.objects.borrow_mut();
            let obj = objs
                .entry((ns.to_string(), name.to_string()))
                .or_default();
            obj.data = crate::util::content::Content::new();
            obj.data.write(0, data.clone());
        }
        self.pending.borrow_mut().push(PendingWrite {
            pool: pool.clone(),
            ns: ns.to_string(),
            name: name.to_string(),
            data,
        });
        Ok(())
    }

    /// `rados_aio_wait_for_complete` over all outstanding aio writes.
    /// Transfers overlap with each other (that's the aio win).
    pub async fn flush_pending(&self) {
        self.ensure_map().await;
        let pending: Vec<PendingWrite> = self.pending.borrow_mut().drain(..).collect();
        if pending.is_empty() {
            return;
        }
        let futs = pending
            .iter()
            .map(|w| {
                boxed(async move {
                    self.write_path(&w.pool, &w.name, w.data.len()).await;
                })
            })
            .collect();
        join_all(futs).await;
        for w in pending {
            let mut objs = w.pool.objects.borrow_mut();
            let obj = objs.entry((w.ns.clone(), w.name.clone())).or_default();
            obj.data = crate::util::content::Content::new();
            obj.data.write(0, w.data);
        }
    }

    /// `rados_read`: `Ok(None)` if absent.
    pub async fn read(
        &self,
        pool: &Rc<CephPool>,
        ns: &str,
        name: &str,
        offset: u64,
        len: u64,
    ) -> Result<Option<Bytes>, RadosError> {
        self.ensure_map().await;
        let (slice, full) = {
            let objs = pool.objects.borrow();
            match objs.get(&(ns.to_string(), name.to_string())) {
                None => return Ok(None),
                Some(o) => {
                    let end = (offset + len).min(o.data.len());
                    let start = offset.min(end);
                    (o.data.read(start, end - start), o.data.len())
                }
            }
        };
        self.read_path(pool, name, slice.len(), full).await;
        Ok(Some(slice))
    }

    /// `rados_stat`: object size, or None.
    pub async fn stat(
        &self,
        pool: &Rc<CephPool>,
        ns: &str,
        name: &str,
    ) -> Result<Option<u64>, RadosError> {
        self.ensure_map().await;
        self.sys.tcp.rpc_rtt(&self.sys.sim).await;
        Ok(pool
            .objects
            .borrow()
            .get(&(ns.to_string(), name.to_string()))
            .map(|o| o.data.len()))
    }

    pub async fn remove(&self, pool: &Rc<CephPool>, ns: &str, name: &str) -> bool {
        self.ensure_map().await;
        self.write_path(pool, name, 64).await;
        pool.objects
            .borrow_mut()
            .remove(&(ns.to_string(), name.to_string()))
            .is_some()
    }

    /// List object names in a namespace (PG scan; one RPC per OSD).
    pub async fn list_objects(&self, pool: &Rc<CephPool>, ns: &str) -> Vec<String> {
        self.ensure_map().await;
        let sim = &self.sys.sim;
        for osd in &self.sys.osds {
            self.sys.tcp.msg(sim).await;
            osd.node.cpu_serve(sim, self.osd_service()).await;
            self.sys.tcp.msg(sim).await;
        }
        pool.objects
            .borrow()
            .keys()
            .filter(|(n, _)| n == ns)
            .map(|(_, name)| name.clone())
            .collect()
    }

    /// Set an object xattr (the 2019 backend attempt's overhead source).
    pub async fn setxattr(
        &self,
        pool: &Rc<CephPool>,
        ns: &str,
        name: &str,
        key: &str,
        value: &[u8],
    ) {
        self.ensure_map().await;
        self.write_path(pool, name, (key.len() + value.len()) as u64 + 256)
            .await;
        let mut objs = pool.objects.borrow_mut();
        let obj = objs
            .entry((ns.to_string(), name.to_string()))
            .or_default();
        obj.xattrs.insert(key.to_string(), value.to_vec());
    }

    pub(crate) fn obj_mut_content<R>(
        &self,
        pool: &Rc<CephPool>,
        ns: &str,
        name: &str,
        f: impl FnOnce(&mut RadosObj) -> R,
    ) -> R {
        let mut objs = pool.objects.borrow_mut();
        let obj = objs
            .entry((ns.to_string(), name.to_string()))
            .or_default();
        f(obj)
    }

    pub(crate) fn obj_content<R>(
        &self,
        pool: &Rc<CephPool>,
        ns: &str,
        name: &str,
        f: impl FnOnce(Option<&RadosObj>) -> R,
    ) -> R {
        let objs = pool.objects.borrow();
        f(objs.get(&(ns.to_string(), name.to_string())))
    }

    /// Leak check helper for tests.
    pub fn pending_count(&self) -> usize {
        self.pending.borrow().len()
    }
}


#[cfg(test)]
mod tests {
    use super::super::testutil::small;
    use super::super::Redundancy;
    use super::*;

    #[test]
    fn write_read_roundtrip() {
        let (sim, ceph, c) = small();
        let pool = ceph.create_pool("p", 512, Redundancy::None);
        let node = c.client_nodes().next().unwrap().clone();
        sim.spawn(async move {
            let cli = ceph.client(&node);
            cli.write_full(&pool, "ns", "obj", b"ceph bytes").await.unwrap();
            let got = cli.read(&pool, "ns", "obj", 0, 10).await.unwrap();
            assert_eq!(got.map(|b| b.to_vec()).as_deref(), Some(b"ceph bytes".as_ref()));
            assert_eq!(cli.stat(&pool, "ns", "obj").await.unwrap(), Some(10));
            assert!(cli.read(&pool, "ns", "missing", 0, 1).await.unwrap().is_none());
        });
        sim.run();
    }

    #[test]
    fn namespaces_isolate_names() {
        let (sim, ceph, c) = small();
        let pool = ceph.create_pool("p", 512, Redundancy::None);
        let node = c.client_nodes().next().unwrap().clone();
        sim.spawn(async move {
            let cli = ceph.client(&node);
            cli.write_full(&pool, "ns1", "x", b"one").await.unwrap();
            cli.write_full(&pool, "ns2", "x", b"two").await.unwrap();
            assert_eq!(
                cli.read(&pool, "ns1", "x", 0, 3).await.unwrap().map(|b| b.to_vec()).as_deref(),
                Some(b"one".as_ref())
            );
            assert_eq!(
                cli.read(&pool, "ns2", "x", 0, 3).await.unwrap().map(|b| b.to_vec()).as_deref(),
                Some(b"two".as_ref())
            );
            let mut l1 = cli.list_objects(&pool, "ns1").await;
            l1.sort();
            assert_eq!(l1, vec!["x"]);
        });
        sim.run();
    }

    #[test]
    fn object_size_limit_enforced() {
        let (sim, ceph, c) = small();
        let pool = ceph.create_pool("p", 512, Redundancy::None);
        let node = c.client_nodes().next().unwrap().clone();
        sim.spawn(async move {
            let cli = ceph.client(&node);
            let big = vec![0u8; (128 << 20) + 1];
            assert_eq!(
                cli.write_full(&pool, "ns", "big", &big).await.unwrap_err(),
                RadosError::ObjectTooLarge
            );
        });
        sim.run();
    }

    #[test]
    fn replica_write_slower_than_none() {
        let run = |red: Redundancy| {
            let (sim, ceph, c) = small();
            let pool = ceph.create_pool("p", 512, red);
            let node = c.client_nodes().next().unwrap().clone();
            sim.spawn(async move {
                let cli = ceph.client(&node);
                for i in 0..50 {
                    cli.write_full(&pool, "ns", &format!("o{i}"), &vec![1u8; 1 << 20])
                        .await
                        .unwrap();
                }
            });
            sim.run()
        };
        let none = run(Redundancy::None);
        let rep2 = run(Redundancy::Replica(2));
        assert!(
            rep2.as_nanos() > (none.as_nanos() as f64 * 1.2) as u64,
            "rep2 {rep2} vs none {none}"
        );
    }

    #[test]
    fn aio_durable_only_after_flush() {
        let (sim, ceph, c) = small();
        let pool = ceph.create_pool("p", 512, Redundancy::None);
        let node = c.client_nodes().next().unwrap().clone();
        sim.spawn(async move {
            let cli = ceph.client(&node);
            cli.aio_write_full(&pool, "ns", "a", b"async").await.unwrap();
            assert_eq!(cli.pending_count(), 1);
            cli.flush_pending().await;
            assert_eq!(cli.pending_count(), 0);
            assert_eq!(
                cli.read(&pool, "ns", "a", 0, 5).await.unwrap().map(|b| b.to_vec()).as_deref(),
                Some(b"async".as_ref())
            );
        });
        sim.run();
    }

    #[test]
    fn aio_visibility_bug_hides_data_until_flush() {
        let (sim, ceph, c) = small();
        let pool = ceph.create_pool("p", 512, Redundancy::None);
        let node = c.client_nodes().next().unwrap().clone();
        sim.spawn(async move {
            let mut cli = ceph.client(&node);
            cli.aio_visibility_bug = true;
            cli.aio_write_full(&pool, "ns", "a", b"late").await.unwrap();
            // another reader does NOT see it yet — the Fig 3.5 failure
            let rdr = ceph.client(&node);
            assert!(rdr.read(&pool, "ns", "a", 0, 4).await.unwrap().is_none());
            cli.flush_pending().await;
            assert!(rdr.read(&pool, "ns", "a", 0, 4).await.unwrap().is_some());
        });
        sim.run();
    }

    #[test]
    fn ec_partial_read_fetches_full_object() {
        // EC read of 1 KiB from a 64 MiB object must cost ~the full object
        let run = |red: Redundancy| {
            let (sim, ceph, c) = small();
            let pool = ceph.create_pool("p", 512, red);
            let node = c.client_nodes().next().unwrap().clone();
            sim.spawn(async move {
                let cli = ceph.client(&node);
                cli.write_full(&pool, "ns", "o", &vec![1u8; 64 << 20])
                    .await
                    .unwrap();
                let t0 = cli.sys.sim.now();
                cli.read(&pool, "ns", "o", 0, 1024).await.unwrap();
                let dt = cli.sys.sim.now() - t0;
                // stash in an xattr-free way: assert here directly
                match red {
                    Redundancy::Erasure(..) => {
                        assert!(dt > SimTime::millis(10), "EC partial read {dt}")
                    }
                    _ => assert!(dt < SimTime::millis(10), "replica partial read {dt}"),
                }
            });
            sim.run()
        };
        run(Redundancy::None);
        run(Redundancy::Erasure(2, 1));
    }
}
