//! Simulated Ceph RADOS (thesis §2.4).
//!
//! Mechanisms modeled:
//!
//! * **Monitor + OSDMap** — clients fetch the map once (a Paxos-backed
//!   monitor round trip), then place objects client-side.
//! * **PG-based CRUSH placement** — `pg = hash(name) % pg_num`, each PG
//!   maps to an ordered OSD set; per-pool replication / 2+1 EC.
//! * **Primary-copy writes** — the client sends data to the primary OSD,
//!   which persists locally, fans out to replicas/EC shards, and acks
//!   only after all are durable (the extra round trips behind Ceph's
//!   write gap vs DAOS in Figs 4.21/4.27).
//! * **TCP-only fabric** — RADOS cannot exploit PSM2/RDMA; all transfers
//!   pay the kernel TCP costs regardless of the cluster interconnect.
//! * **Omaps** — key-value objects on the primary OSD;
//!   `omap_get_vals_by_keys` can fetch all entries in one RPC (richer
//!   than DAOS KV listing — thesis §3.2.1).
//! * **Object-size limit** — 128 MiB default (`osd_max_object_size`),
//!   configurable at deployment; oversized writes are rejected.
//! * **PG-count sensitivity** — service times scale by a penalty factor
//!   when PGs/OSD strays from the ~100 sweet spot (empirical knob).
//!
//! Object/omap contents are real bytes; only time is simulated.

mod omap;
mod rados;

pub use rados::{RadosError, RadosClient};

use std::cell::{Cell, RefCell};
use std::collections::HashMap;
use std::rc::Rc;

use crate::hw::cluster::Cluster;
use crate::hw::fabric::{Fabric, FabricKind};
use crate::hw::node::Node;
use crate::sim::exec::Sim;
use crate::sim::time::SimTime;

/// Pool-level redundancy (RADOS: per-pool, not per-object — unlike DAOS).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Redundancy {
    /// no replication (the thesis' baseline configuration)
    None,
    /// n-way replication (primary + n-1 copies)
    Replica(usize),
    /// k data + m parity erasure coding
    Erasure(usize, usize),
}

impl Redundancy {
    /// Number of OSDs an object touches.
    pub fn width(self) -> usize {
        match self {
            Redundancy::None => 1,
            Redundancy::Replica(n) => n,
            Redundancy::Erasure(k, m) => k + m,
        }
    }
}

#[derive(Clone, Copy, Debug)]
pub struct CephCosts {
    /// client-side per-op CPU (librados path)
    pub client_op: SimTime,
    /// OSD per-op service (BlueStore + messenger)
    pub osd_op: SimTime,
    /// monitor map fetch handling
    pub mon_fetch: SimTime,
    /// per-omap-entry media overhead
    pub omap_entry_overhead: u64,
}

impl Default for CephCosts {
    fn default() -> Self {
        CephCosts {
            client_op: SimTime::micros(3),
            osd_op: SimTime::micros(15),
            mon_fetch: SimTime::millis(1),
            omap_entry_overhead: 128,
        }
    }
}

#[derive(Clone, Debug)]
pub struct CephConfig {
    /// OSD daemons per storage node
    pub osds_per_node: usize,
    /// `osd_max_object_size` (default 128 MiB)
    pub max_object_size: u64,
    pub costs: CephCosts,
}

impl Default for CephConfig {
    fn default() -> Self {
        CephConfig {
            osds_per_node: 1,
            max_object_size: 128 << 20,
            costs: CephCosts::default(),
        }
    }
}

/// A RADOS object: regular byte blob and/or omap entries.
#[derive(Default)]
pub(crate) struct RadosObj {
    pub data: crate::util::content::Content,
    pub omap: HashMap<String, Vec<u8>>,
    pub xattrs: HashMap<String, Vec<u8>>,
}

/// A RADOS pool: PG count, redundancy, and its object namespace(s).
pub struct CephPool {
    pub name: String,
    pub pg_num: usize,
    pub redundancy: Redundancy,
    /// key: (namespace, object name)
    pub(crate) objects: RefCell<HashMap<(String, String), RadosObj>>,
}

pub(crate) struct Osd {
    pub node: Rc<Node>,
}

/// The deployed RADOS cluster.
pub struct Ceph {
    pub sim: Sim,
    pub cluster: Rc<Cluster>,
    pub config: CephConfig,
    /// RADOS always speaks TCP, whatever the cluster fabric is.
    pub(crate) tcp: Rc<Fabric>,
    pub(crate) osds: Vec<Osd>,
    pub(crate) mon_node: Rc<Node>,
    pub(crate) pools: RefCell<HashMap<String, Rc<CephPool>>>,
    pub(crate) ops: Cell<u64>,
    /// unique client-instance ids (process identity for object naming)
    pub(crate) next_client: Cell<u64>,
}

impl Ceph {
    pub fn deploy(sim: &Sim, cluster: &Rc<Cluster>, config: CephConfig) -> Rc<Ceph> {
        let mut osds = Vec::new();
        for node in cluster.storage_nodes() {
            for _ in 0..config.osds_per_node {
                osds.push(Osd { node: node.clone() });
            }
        }
        assert!(!osds.is_empty(), "ceph needs storage nodes");
        let mon_node = cluster
            .metadata_nodes()
            .next()
            .or_else(|| cluster.storage_nodes().next())
            .unwrap()
            .clone();
        // TCP-only fabric: mirror the testbed's TCP flavour
        let tcp_kind = match cluster.fabric.spec.kind {
            FabricKind::TcpGcp => FabricKind::TcpGcp,
            _ => FabricKind::TcpOpa,
        };
        Rc::new(Ceph {
            sim: sim.clone(),
            cluster: cluster.clone(),
            config,
            tcp: Fabric::new(tcp_kind),
            osds,
            mon_node,
            pools: RefCell::new(HashMap::new()),
            ops: Cell::new(0),
            next_client: Cell::new(0),
        })
    }

    /// `ceph osd pool create` — admin op, outside measured windows.
    pub fn create_pool(&self, name: &str, pg_num: usize, redundancy: Redundancy) -> Rc<CephPool> {
        let pool = Rc::new(CephPool {
            name: name.to_string(),
            pg_num,
            redundancy,
            objects: RefCell::new(HashMap::new()),
        });
        self.pools
            .borrow_mut()
            .insert(name.to_string(), pool.clone());
        pool
    }

    pub fn delete_pool(&self, name: &str) -> bool {
        self.pools.borrow_mut().remove(name).is_some()
    }

    /// The replicated metadata pool (created on demand) used by omap
    /// consumers when the data pool is erasure-coded.
    pub fn meta_pool(&self) -> Rc<CephPool> {
        if let Some(p) = self.pools.borrow().get("fdb-meta") {
            return p.clone();
        }
        self.create_pool("fdb-meta", 128, Redundancy::None)
    }

    pub fn osd_count(&self) -> usize {
        self.osds.len()
    }

    pub fn total_ops(&self) -> u64 {
        self.ops.get()
    }

    /// Total PGs across pools (performance-sensitivity input).
    pub fn total_pgs(&self) -> usize {
        self.pools.borrow().values().map(|p| p.pg_num).sum()
    }

    /// Service-time penalty for PG-count imbalance: 1.0 at ~100 PGs/OSD,
    /// growing with |log2(ratio)| (empirical; thesis §2.4 and §3.2 note
    /// RADOS "can be very sensitive" to this parameter).
    pub(crate) fn pg_penalty(&self) -> f64 {
        let per_osd = self.total_pgs() as f64 / self.osds.len() as f64;
        if per_osd <= 0.0 {
            return 1.0;
        }
        let dev = (per_osd / 100.0).log2().abs();
        1.0 + 0.15 * dev
    }

    /// CRUSH-like mapping: pg → ordered OSD set of size `width`.
    pub(crate) fn osds_for(&self, pool: &CephPool, name: &str) -> Vec<usize> {
        let n = self.osds.len();
        let pg = (hash_name(name) % pool.pg_num as u64) as usize;
        let width = pool.redundancy.width().min(n);
        // deterministic pseudo-random walk seeded by (pool, pg)
        let mut state = hash_name(&pool.name) ^ (pg as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15);
        let mut out = Vec::with_capacity(width);
        while out.len() < width {
            state = crate::util::rng::splitmix64(&mut state);
            let cand = (state % n as u64) as usize;
            if !out.contains(&cand) {
                out.push(cand);
            }
        }
        out
    }
}

/// Stable 64-bit name hash (FNV-1a). Shared by CRUSH placement and the
/// FDB DAOS catalogue's collocation→OID mapping.
pub fn hash_name(s: &str) -> u64 {
    let mut h = 0xcbf2_9ce4_8422_2325u64;
    for b in s.as_bytes() {
        h ^= *b as u64;
        h = h.wrapping_mul(0x1000_0000_01b3);
    }
    h
}

#[cfg(test)]
pub(crate) mod testutil {
    use super::*;
    use crate::hw::profiles::{build_cluster, Testbed};

    pub fn small() -> (Sim, Rc<Ceph>, Rc<Cluster>) {
        let sim = Sim::new();
        let cluster = Rc::new(build_cluster(Testbed::Gcp, 4, 2, true, true));
        let ceph = Ceph::deploy(&sim, &cluster, CephConfig::default());
        (sim, ceph, cluster)
    }
}

#[cfg(test)]
mod tests {
    use super::testutil::small;
    use super::*;

    #[test]
    fn deploy_counts() {
        let (_s, ceph, _c) = small();
        assert_eq!(ceph.osd_count(), 4);
    }

    #[test]
    fn crush_is_deterministic_distinct_and_spread() {
        let (_s, ceph, _c) = small();
        let pool = ceph.create_pool("p", 512, Redundancy::Replica(3));
        let a = ceph.osds_for(&pool, "obj-1");
        let b = ceph.osds_for(&pool, "obj-1");
        assert_eq!(a, b);
        assert_eq!(a.len(), 3);
        let mut uniq = a.clone();
        uniq.sort_unstable();
        uniq.dedup();
        assert_eq!(uniq.len(), 3, "replicas on distinct OSDs");
        // different names spread primaries
        let mut primaries = std::collections::HashSet::new();
        for i in 0..64 {
            primaries.insert(ceph.osds_for(&pool, &format!("obj-{i}"))[0]);
        }
        assert_eq!(primaries.len(), 4);
    }

    #[test]
    fn pg_penalty_is_one_at_sweet_spot() {
        let (_s, ceph, _c) = small();
        ceph.create_pool("p", 400, Redundancy::None); // 100/OSD
        assert!((ceph.pg_penalty() - 1.0).abs() < 1e-9);
        ceph.create_pool("q", 400, Redundancy::None); // now 200/OSD
        assert!(ceph.pg_penalty() > 1.1);
    }

    #[test]
    fn redundancy_width() {
        assert_eq!(Redundancy::None.width(), 1);
        assert_eq!(Redundancy::Replica(2).width(), 2);
        assert_eq!(Redundancy::Erasure(2, 1).width(), 3);
    }
}
