//! RADOS omap operations: key-value entries attached to an object,
//! served by the object's primary OSD. Richer than DAOS KVs: a single
//! call can return all keys *and* values (thesis §3.2.1 — this is what
//! made the Ceph backend's `list()` more efficient).
//!
//! Omaps cannot live in EC pools (librados restriction, §2.4).

use std::collections::HashMap;
use std::rc::Rc;

use super::rados::{RadosClient, RadosError};
use super::{CephPool, Redundancy};

impl RadosClient {
    /// `rados_write_op_omap_set2`: insert/overwrite entries, durable on
    /// return. Creates the object if needed (write_op create + omap_set).
    pub async fn omap_set(
        &self,
        pool: &Rc<CephPool>,
        ns: &str,
        name: &str,
        entries: &[(&str, &[u8])],
    ) -> Result<(), RadosError> {
        if matches!(pool.redundancy, Redundancy::Erasure(..)) {
            return Err(RadosError::NoSuchPool); // omaps unsupported on EC pools
        }
        self.ensure_map().await;
        let bytes: u64 = entries
            .iter()
            .map(|(k, v)| k.len() as u64 + v.len() as u64 + self.sys.config.costs.omap_entry_overhead)
            .sum();
        self.write_path(pool, name, bytes).await;
        self.obj_mut_content(pool, ns, name, |o| {
            for (k, v) in entries {
                o.omap.insert(k.to_string(), v.to_vec());
            }
        });
        Ok(())
    }

    /// `rados_read_op_omap_get_vals_by_keys2`: fetch specific keys.
    pub async fn omap_get(
        &self,
        pool: &Rc<CephPool>,
        ns: &str,
        name: &str,
        keys: &[&str],
    ) -> Result<HashMap<String, Vec<u8>>, RadosError> {
        self.ensure_map().await;
        let out: HashMap<String, Vec<u8>> = self.obj_content(pool, ns, name, |o| {
            o.map(|o| {
                keys.iter()
                    .filter_map(|k| o.omap.get(*k).map(|v| (k.to_string(), v.clone())))
                    .collect()
            })
            .unwrap_or_default()
        });
        let bytes: u64 = out
            .iter()
            .map(|(k, v)| (k.len() + v.len()) as u64)
            .sum::<u64>()
            + 64;
        self.read_path(pool, name, bytes, bytes).await;
        Ok(out)
    }

    /// Fetch ALL entries (keys and values) in a single RPC — the
    /// capability DAOS KVs lack.
    pub async fn omap_get_all(
        &self,
        pool: &Rc<CephPool>,
        ns: &str,
        name: &str,
    ) -> Result<HashMap<String, Vec<u8>>, RadosError> {
        self.ensure_map().await;
        let out: HashMap<String, Vec<u8>> = self.obj_content(pool, ns, name, |o| {
            o.map(|o| o.omap.clone()).unwrap_or_default()
        });
        let bytes: u64 = out
            .iter()
            .map(|(k, v)| (k.len() + v.len()) as u64)
            .sum::<u64>()
            + 64;
        self.read_path(pool, name, bytes, bytes).await;
        Ok(out)
    }

    /// `rados_read_op_omap_get_keys2`.
    pub async fn omap_keys(
        &self,
        pool: &Rc<CephPool>,
        ns: &str,
        name: &str,
    ) -> Result<Vec<String>, RadosError> {
        self.ensure_map().await;
        let keys: Vec<String> = self.obj_content(pool, ns, name, |o| {
            o.map(|o| o.omap.keys().cloned().collect()).unwrap_or_default()
        });
        let bytes = keys.iter().map(|k| k.len() as u64).sum::<u64>() + 64;
        self.read_path(pool, name, bytes, bytes).await;
        Ok(keys)
    }

    /// `rados_write_op_omap_rm_keys2`.
    pub async fn omap_rm(
        &self,
        pool: &Rc<CephPool>,
        ns: &str,
        name: &str,
        keys: &[&str],
    ) -> Result<(), RadosError> {
        self.ensure_map().await;
        self.write_path(pool, name, keys.iter().map(|k| k.len() as u64 + 32).sum())
            .await;
        self.obj_mut_content(pool, ns, name, |o| {
            for k in keys {
                o.omap.remove(*k);
            }
        });
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::super::testutil::small;
    use super::*;

    #[test]
    fn omap_set_get_all() {
        let (sim, ceph, c) = small();
        let pool = ceph.create_pool("p", 512, Redundancy::None);
        let node = c.client_nodes().next().unwrap().clone();
        sim.spawn(async move {
            let cli = ceph.client(&node);
            cli.omap_set(&pool, "ns", "idx", &[("step=1", b"loc1"), ("step=2", b"loc2")])
                .await
                .unwrap();
            let all = cli.omap_get_all(&pool, "ns", "idx").await.unwrap();
            assert_eq!(all.len(), 2);
            assert_eq!(all["step=1"], b"loc1");
            let got = cli.omap_get(&pool, "ns", "idx", &["step=2"]).await.unwrap();
            assert_eq!(got.len(), 1);
            assert_eq!(got["step=2"], b"loc2");
            let mut keys = cli.omap_keys(&pool, "ns", "idx").await.unwrap();
            keys.sort();
            assert_eq!(keys, vec!["step=1", "step=2"]);
        });
        sim.run();
    }

    #[test]
    fn omap_overwrite_and_remove() {
        let (sim, ceph, c) = small();
        let pool = ceph.create_pool("p", 512, Redundancy::None);
        let node = c.client_nodes().next().unwrap().clone();
        sim.spawn(async move {
            let cli = ceph.client(&node);
            cli.omap_set(&pool, "ns", "i", &[("k", b"v1")]).await.unwrap();
            cli.omap_set(&pool, "ns", "i", &[("k", b"v2")]).await.unwrap();
            let all = cli.omap_get_all(&pool, "ns", "i").await.unwrap();
            assert_eq!(all["k"], b"v2");
            cli.omap_rm(&pool, "ns", "i", &["k"]).await.unwrap();
            assert!(cli.omap_get_all(&pool, "ns", "i").await.unwrap().is_empty());
        });
        sim.run();
    }

    #[test]
    fn omap_rejected_on_ec_pool() {
        let (sim, ceph, c) = small();
        let pool = ceph.create_pool("p", 512, Redundancy::Erasure(2, 1));
        let node = c.client_nodes().next().unwrap().clone();
        sim.spawn(async move {
            let cli = ceph.client(&node);
            assert!(cli.omap_set(&pool, "ns", "i", &[("k", b"v")]).await.is_err());
        });
        sim.run();
    }
}
