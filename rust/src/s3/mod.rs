//! S3 protocol layer (thesis §3.3): buckets + objects over HTTP.
//!
//! Two providers:
//! * [`MemS3`] — a MinIO-like standalone store on one node (what the
//!   thesis verified the FDB S3 backend against);
//! * [`RgwS3`] — the Ceph RADOS Gateway: S3 ops translate to RADOS ops,
//!   paying an extra HTTP hop through a gateway node.
//!
//! Both enforce S3 semantics: PUT is all-or-nothing and replaces,
//! objects are immutable (no append), GET supports byte ranges,
//! multipart uploads assemble parts on completion.

use std::cell::RefCell;
use std::collections::HashMap;
use std::rc::Rc;

use crate::ceph::{Ceph, CephPool, RadosClient};
use crate::hw::fabric::{Fabric, FabricKind};
use crate::hw::node::Node;
use crate::sim::exec::Sim;
use crate::sim::time::SimTime;
use crate::util::content::Bytes;

/// HTTP request overhead per S3 operation (parse/auth/sign).
const HTTP_OP: SimTime = SimTime(200_000); // 200 µs

/// S3 errors.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum S3Error {
    NoSuchBucket,
    NoSuchKey,
    NoSuchUpload,
}

impl std::fmt::Display for S3Error {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "{self:?}")
    }
}
impl std::error::Error for S3Error {}

/// The S3 API surface used by the FDB S3 Store backend.
#[allow(async_fn_in_trait)] // single-threaded DES: no Send bounds needed
pub trait S3Api {
    async fn create_bucket(&self, bucket: &str);
    async fn put_object(&self, bucket: &str, key: &str, data: Bytes) -> Result<(), S3Error>;
    async fn get_object(
        &self,
        bucket: &str,
        key: &str,
        range: Option<(u64, u64)>,
    ) -> Result<Option<Bytes>, S3Error>;
    async fn head_object(&self, bucket: &str, key: &str) -> Result<Option<u64>, S3Error>;
    async fn delete_object(&self, bucket: &str, key: &str) -> Result<(), S3Error>;
    async fn list_objects(&self, bucket: &str, prefix: &str) -> Result<Vec<String>, S3Error>;
}

// ---------------------------------------------------------------- MemS3

struct MemBucket {
    objects: HashMap<String, Bytes>,
    uploads: HashMap<u64, Vec<(u32, Bytes)>>,
}

/// MinIO-like single-node S3 store.
pub struct MemS3 {
    sim: Sim,
    fabric: Rc<Fabric>,
    pub server: Rc<Node>,
    client_node: Rc<Node>,
    buckets: RefCell<HashMap<String, MemBucket>>,
    next_upload: std::cell::Cell<u64>,
}

impl MemS3 {
    pub fn new(sim: &Sim, server: &Rc<Node>, client_node: &Rc<Node>) -> MemS3 {
        MemS3 {
            sim: sim.clone(),
            fabric: Fabric::new(FabricKind::TcpGcp),
            server: server.clone(),
            client_node: client_node.clone(),
            buckets: RefCell::new(HashMap::new()),
            next_upload: std::cell::Cell::new(1),
        }
    }

    async fn http(&self, payload_up: u64, payload_down: u64) {
        self.sim.sleep(HTTP_OP).await;
        self.fabric
            .xfer(&self.sim, &self.client_node.nic, &self.server.nic, payload_up.max(512))
            .await;
        self.server.cpu_serve(&self.sim, SimTime::micros(50)).await;
        self.fabric
            .xfer(&self.sim, &self.server.nic, &self.client_node.nic, payload_down.max(512))
            .await;
    }

    /// Initiate a multipart upload; returns the upload id.
    pub async fn create_multipart(&self, bucket: &str, _key: &str) -> Result<u64, S3Error> {
        self.http(512, 512).await;
        if !self.buckets.borrow().contains_key(bucket) {
            return Err(S3Error::NoSuchBucket);
        }
        let id = self.next_upload.get();
        self.next_upload.set(id + 1);
        self.buckets
            .borrow_mut()
            .get_mut(bucket)
            .unwrap()
            .uploads
            .insert(id, Vec::new());
        Ok(id)
    }

    /// Upload one part; returns the part number.
    pub async fn upload_part(
        &self,
        bucket: &str,
        upload: u64,
        part_no: u32,
        data: Bytes,
    ) -> Result<u32, S3Error> {
        self.http(data.len(), 512).await;
        self.server.dev().write(&self.sim, data.len()).await;
        let mut buckets = self.buckets.borrow_mut();
        let b = buckets.get_mut(bucket).ok_or(S3Error::NoSuchBucket)?;
        let parts = b.uploads.get_mut(&upload).ok_or(S3Error::NoSuchUpload)?;
        parts.push((part_no, data));
        Ok(part_no)
    }

    /// Complete: assemble parts (in part-number order) into the object.
    pub async fn complete_multipart(
        &self,
        bucket: &str,
        key: &str,
        upload: u64,
    ) -> Result<(), S3Error> {
        self.http(512, 512).await;
        let mut buckets = self.buckets.borrow_mut();
        let b = buckets.get_mut(bucket).ok_or(S3Error::NoSuchBucket)?;
        let mut parts = b.uploads.remove(&upload).ok_or(S3Error::NoSuchUpload)?;
        parts.sort_by_key(|(n, _)| *n);
        let mut data = Bytes::new();
        for (_, d) in parts {
            data.append(d);
        }
        b.objects.insert(key.to_string(), data);
        Ok(())
    }
}

impl S3Api for MemS3 {
    async fn create_bucket(&self, bucket: &str) {
        self.http(512, 512).await;
        self.buckets
            .borrow_mut()
            .entry(bucket.to_string())
            .or_insert_with(|| MemBucket {
                objects: HashMap::new(),
                uploads: HashMap::new(),
            });
    }

    async fn put_object(&self, bucket: &str, key: &str, data: Bytes) -> Result<(), S3Error> {
        self.http(data.len(), 512).await;
        self.server.dev().write(&self.sim, data.len()).await;
        let mut buckets = self.buckets.borrow_mut();
        let b = buckets.get_mut(bucket).ok_or(S3Error::NoSuchBucket)?;
        // all-or-nothing replace: last racing PUT prevails
        b.objects.insert(key.to_string(), data);
        Ok(())
    }

    async fn get_object(
        &self,
        bucket: &str,
        key: &str,
        range: Option<(u64, u64)>,
    ) -> Result<Option<Bytes>, S3Error> {
        let data = {
            let buckets = self.buckets.borrow();
            let b = buckets.get(bucket).ok_or(S3Error::NoSuchBucket)?;
            match b.objects.get(key) {
                None => return Ok(None),
                Some(d) => match range {
                    None => d.clone(),
                    Some((off, len)) => d.slice(off, len),
                },
            }
        };
        self.server.dev().read(&self.sim, data.len()).await;
        self.http(512, data.len()).await;
        Ok(Some(data))
    }

    async fn head_object(&self, bucket: &str, key: &str) -> Result<Option<u64>, S3Error> {
        self.http(512, 512).await;
        let buckets = self.buckets.borrow();
        let b = buckets.get(bucket).ok_or(S3Error::NoSuchBucket)?;
        Ok(b.objects.get(key).map(|d| d.len()))
    }

    async fn delete_object(&self, bucket: &str, key: &str) -> Result<(), S3Error> {
        self.http(512, 512).await;
        let mut buckets = self.buckets.borrow_mut();
        let b = buckets.get_mut(bucket).ok_or(S3Error::NoSuchBucket)?;
        b.objects.remove(key);
        Ok(())
    }

    async fn list_objects(&self, bucket: &str, prefix: &str) -> Result<Vec<String>, S3Error> {
        self.http(512, 4096).await;
        let buckets = self.buckets.borrow();
        let b = buckets.get(bucket).ok_or(S3Error::NoSuchBucket)?;
        Ok(b.objects
            .keys()
            .filter(|k| k.starts_with(prefix))
            .cloned()
            .collect())
    }
}

// ---------------------------------------------------------------- RgwS3

/// RADOS Gateway: S3 ops forwarded to a RADOS pool; bucket → namespace.
pub struct RgwS3 {
    sim: Sim,
    pub gateway: Rc<Node>,
    client_node: Rc<Node>,
    rados: RadosClient,
    pool: Rc<CephPool>,
    http: Rc<Fabric>,
}

impl RgwS3 {
    pub fn new(
        sim: &Sim,
        ceph: &Rc<Ceph>,
        pool: &Rc<CephPool>,
        gateway: &Rc<Node>,
        client_node: &Rc<Node>,
    ) -> RgwS3 {
        RgwS3 {
            sim: sim.clone(),
            gateway: gateway.clone(),
            client_node: client_node.clone(),
            // the RGW daemon is the RADOS client, running on the gateway
            rados: ceph.client(gateway),
            pool: pool.clone(),
            http: Fabric::new(FabricKind::TcpGcp),
        }
    }

    async fn hop(&self, up: u64, down: u64) {
        self.sim.sleep(HTTP_OP).await;
        self.http
            .xfer(&self.sim, &self.client_node.nic, &self.gateway.nic, up.max(512))
            .await;
        self.gateway.cpu_serve(&self.sim, SimTime::micros(80)).await;
        self.http
            .xfer(&self.sim, &self.gateway.nic, &self.client_node.nic, down.max(512))
            .await;
    }
}

impl S3Api for RgwS3 {
    async fn create_bucket(&self, _bucket: &str) {
        self.hop(512, 512).await;
    }

    async fn put_object(&self, bucket: &str, key: &str, data: Bytes) -> Result<(), S3Error> {
        self.hop(data.len(), 512).await;
        self.rados
            .write_full_data(&self.pool, bucket, key, data)
            .await
            .map_err(|_| S3Error::NoSuchBucket)?;
        Ok(())
    }

    async fn get_object(
        &self,
        bucket: &str,
        key: &str,
        range: Option<(u64, u64)>,
    ) -> Result<Option<Bytes>, S3Error> {
        let (off, len) = range.unwrap_or((0, u64::MAX / 2));
        let got = self
            .rados
            .read(&self.pool, bucket, key, off, len)
            .await
            .map_err(|_| S3Error::NoSuchBucket)?;
        let down = got.as_ref().map(|d| d.len()).unwrap_or(0);
        self.hop(512, down).await;
        Ok(got)
    }

    async fn head_object(&self, bucket: &str, key: &str) -> Result<Option<u64>, S3Error> {
        self.hop(512, 512).await;
        self.rados
            .stat(&self.pool, bucket, key)
            .await
            .map_err(|_| S3Error::NoSuchBucket)
    }

    async fn delete_object(&self, bucket: &str, key: &str) -> Result<(), S3Error> {
        self.hop(512, 512).await;
        self.rados.remove(&self.pool, bucket, key).await;
        Ok(())
    }

    async fn list_objects(&self, bucket: &str, prefix: &str) -> Result<Vec<String>, S3Error> {
        self.hop(512, 4096).await;
        Ok(self
            .rados
            .list_objects(&self.pool, bucket)
            .await
            .into_iter()
            .filter(|k| k.starts_with(prefix))
            .collect())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ceph::{CephConfig, Redundancy};
    use crate::hw::profiles::{build_cluster, Testbed};

    fn mem_setup() -> (Sim, Rc<MemS3>) {
        let sim = Sim::new();
        let cluster = Rc::new(build_cluster(Testbed::Gcp, 1, 1, false, true));
        let server = cluster.storage_nodes().next().unwrap().clone();
        let client = cluster.client_nodes().next().unwrap().clone();
        let s3 = Rc::new(MemS3::new(&sim, &server, &client));
        (sim, s3)
    }

    #[test]
    fn put_get_head_delete() {
        let (sim, s3) = mem_setup();
        sim.spawn(async move {
            s3.create_bucket("fdb-ds1").await;
            s3.put_object("fdb-ds1", "field-1", Bytes::real(b"grib-bytes".to_vec())).await.unwrap();
            assert_eq!(
                s3.get_object("fdb-ds1", "field-1", None).await.unwrap().map(|b| b.to_vec()).as_deref(),
                Some(b"grib-bytes".as_ref())
            );
            assert_eq!(
                s3.get_object("fdb-ds1", "field-1", Some((5, 5))).await.unwrap().map(|b| b.to_vec()).as_deref(),
                Some(b"bytes".as_ref())
            );
            assert_eq!(s3.head_object("fdb-ds1", "field-1").await.unwrap(), Some(10));
            s3.delete_object("fdb-ds1", "field-1").await.unwrap();
            assert!(s3.get_object("fdb-ds1", "field-1", None).await.unwrap().is_none());
        });
        sim.run();
    }

    #[test]
    fn put_replaces_whole_object() {
        let (sim, s3) = mem_setup();
        sim.spawn(async move {
            s3.create_bucket("b").await;
            s3.put_object("b", "k", Bytes::real(b"version-1".to_vec())).await.unwrap();
            s3.put_object("b", "k", Bytes::real(b"v2".to_vec())).await.unwrap();
            assert_eq!(
                s3.get_object("b", "k", None).await.unwrap().map(|b| b.to_vec()).as_deref(),
                Some(b"v2".as_ref())
            );
        });
        sim.run();
    }

    #[test]
    fn multipart_assembles_in_order() {
        let (sim, s3) = mem_setup();
        sim.spawn(async move {
            s3.create_bucket("b").await;
            let up = s3.create_multipart("b", "k").await.unwrap();
            // upload out of order
            s3.upload_part("b", up, 2, Bytes::real(b"world".to_vec())).await.unwrap();
            s3.upload_part("b", up, 1, Bytes::real(b"hello ".to_vec())).await.unwrap();
            s3.complete_multipart("b", "k", up).await.unwrap();
            assert_eq!(
                s3.get_object("b", "k", None).await.unwrap().map(|b| b.to_vec()).as_deref(),
                Some(b"hello world".as_ref())
            );
        });
        sim.run();
    }

    #[test]
    fn missing_bucket_errors() {
        let (sim, s3) = mem_setup();
        sim.spawn(async move {
            assert_eq!(
                s3.put_object("nope", "k", Bytes::real(b"x".to_vec())).await.unwrap_err(),
                S3Error::NoSuchBucket
            );
        });
        sim.run();
    }

    #[test]
    fn rgw_roundtrip_over_rados() {
        let sim = Sim::new();
        let cluster = Rc::new(build_cluster(Testbed::Gcp, 2, 1, true, true));
        let ceph = Ceph::deploy(&sim, &cluster, CephConfig::default());
        let pool = ceph.create_pool("rgw", 512, Redundancy::None);
        let gw = cluster.storage_nodes().next().unwrap().clone();
        let client = cluster.client_nodes().next().unwrap().clone();
        let s3 = Rc::new(RgwS3::new(&sim, &ceph, &pool, &gw, &client));
        sim.spawn(async move {
            s3.create_bucket("b").await;
            s3.put_object("b", "k", Bytes::real(b"via-rgw".to_vec())).await.unwrap();
            assert_eq!(
                s3.get_object("b", "k", None).await.unwrap().map(|b| b.to_vec()).as_deref(),
                Some(b"via-rgw".as_ref())
            );
            assert_eq!(s3.head_object("b", "k").await.unwrap(), Some(7));
            let keys = s3.list_objects("b", "").await.unwrap();
            assert_eq!(keys, vec!["k"]);
        });
        sim.run();
    }
}
