//! libdfs: the POSIX files/directories emulation layer over DAOS
//! key-values and arrays (thesis §2.3). Used by the IOR/HDF5 comparison
//! (Fig 4.29): file data lives in an array per file, the namespace in a
//! directory KV. Not fully POSIX (no O_APPEND, no advisory locks) — like
//! the real libdfs.

use std::rc::Rc;

use super::{Container, DaosClient, DaosError, ObjClass, Oid};

/// A DFS mount over one container.
pub struct Dfs<'c> {
    client: &'c DaosClient,
    cont: Rc<Container>,
    /// namespace KV at a reserved OID
    ns_oid: Oid,
}

/// An open DFS file.
pub struct DfsFile {
    pub oid: Oid,
    pub class: ObjClass,
}

const NS_OID: Oid = Oid { hi: u64::MAX, lo: 0 };

impl<'c> Dfs<'c> {
    /// Mount (create-if-needed) a DFS namespace in `cont`.
    pub fn mount(client: &'c DaosClient, cont: &Rc<Container>) -> Dfs<'c> {
        Dfs {
            client,
            cont: cont.clone(),
            ns_oid: NS_OID,
        }
    }

    fn ns(&self) -> super::KvHandle {
        self.client.kv_open(&self.cont, self.ns_oid, ObjClass::S1)
    }

    /// Create a file (overwrites an existing mapping, like dfs_open+CREATE).
    pub async fn create(&self, path: &str, class: ObjClass) -> DfsFile {
        let oid = self.client.alloc_oid(&self.cont).await;
        let mut rec = Vec::with_capacity(17);
        rec.extend_from_slice(&oid.hi.to_le_bytes());
        rec.extend_from_slice(&oid.lo.to_le_bytes());
        rec.push(class_tag(class));
        self.client.kv_put(&self.ns(), path, &rec).await;
        DfsFile { oid, class }
    }

    /// Open an existing file.
    pub async fn open(&self, path: &str) -> Result<Option<DfsFile>, DaosError> {
        let rec = self.client.kv_get(&self.ns(), path).await?;
        Ok(rec.map(|r| {
            let hi = u64::from_le_bytes(r[0..8].try_into().unwrap());
            let lo = u64::from_le_bytes(r[8..16].try_into().unwrap());
            DfsFile {
                oid: Oid::new(hi, lo),
                class: tag_class(r[16]),
            }
        }))
    }

    pub async fn write(&self, f: &DfsFile, offset: u64, data: &[u8]) {
        let arr = self
            .client
            .array_open_with_attr(&self.cont, f.oid, f.class);
        self.client.array_write(&arr, offset, data).await;
    }

    /// Write a (possibly virtual) byte string — bulk IOR/HDF5 path.
    pub async fn write_data(&self, f: &DfsFile, offset: u64, data: crate::util::content::Bytes) {
        let arr = self
            .client
            .array_open_with_attr(&self.cont, f.oid, f.class);
        self.client.array_write_data(&arr, offset, data).await;
    }

    pub async fn read(
        &self,
        f: &DfsFile,
        offset: u64,
        len: u64,
    ) -> Result<crate::util::content::Bytes, DaosError> {
        let arr = self
            .client
            .array_open_with_attr(&self.cont, f.oid, f.class);
        self.client.array_read(&arr, offset, len).await
    }

    pub async fn readdir(&self) -> Vec<String> {
        self.client.kv_list(&self.ns()).await
    }

    pub async fn unlink(&self, path: &str) {
        self.client.kv_remove(&self.ns(), path).await;
    }
}

fn class_tag(c: ObjClass) -> u8 {
    match c {
        ObjClass::S1 => 0,
        ObjClass::S2 => 1,
        ObjClass::Sx => 2,
        ObjClass::Rp2 => 3,
        ObjClass::Ec2p1 => 4,
    }
}

fn tag_class(t: u8) -> ObjClass {
    match t {
        0 => ObjClass::S1,
        1 => ObjClass::S2,
        2 => ObjClass::Sx,
        3 => ObjClass::Rp2,
        _ => ObjClass::Ec2p1,
    }
}

#[cfg(test)]
mod tests {
    use super::super::testutil::small;
    use super::*;

    #[test]
    fn dfs_file_roundtrip() {
        let (sim, d, c) = small();
        d.create_pool("p");
        let node = c.client_nodes().next().unwrap().clone();
        sim.spawn(async move {
            let cli = d.client(&node);
            let pool = cli.pool_connect("p").await.unwrap();
            let cont = cli.cont_create_with_label(&pool, "dfs").await.unwrap();
            let dfs = Dfs::mount(&cli, &cont);
            let f = dfs.create("/exp/out.h5", ObjClass::Sx).await;
            dfs.write(&f, 0, b"hdf5-ish bytes").await;
            let g = dfs.open("/exp/out.h5").await.unwrap().unwrap();
            assert_eq!(g.oid, f.oid);
            assert_eq!(g.class, ObjClass::Sx);
            let got = dfs.read(&g, 0, 14).await.unwrap().to_vec();
            assert_eq!(&got, b"hdf5-ish bytes");
            assert_eq!(dfs.readdir().await, vec!["/exp/out.h5".to_string()]);
            dfs.unlink("/exp/out.h5").await;
            assert!(dfs.open("/exp/out.h5").await.unwrap().is_none());
        });
        sim.run();
    }
}
