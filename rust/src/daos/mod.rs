//! Simulated DAOS object store (thesis §2.3).
//!
//! Models the mechanisms behind DAOS' measured advantages:
//!
//! * **Algorithmic placement** — objects map to targets by OID hash; no
//!   metadata server round trips, every op goes straight to the right
//!   engine.
//! * **MVCC, no locks** — writes create new versions server-side; reads
//!   see the latest committed version. Write+read contention costs
//!   nothing beyond ordinary queueing.
//! * **User-space, zero-copy** — tiny per-op client CPU cost; PSM2/RDMA
//!   fabrics exploited natively.
//! * **Immediate persistence** — an op returns only after the engine has
//!   made it durable; `flush()` is a no-op upstream.
//! * **Object classes** — `OC_S1/S2/SX` striping, `OC_RP_2G1`
//!   replication, `OC_EC_2P1` erasure coding, per object.
//!
//! KV and array contents are real bytes; only time is simulated.

mod array;
pub mod dfs;
mod kv;

pub use array::ArrayHandle;
pub use kv::KvHandle;

use std::cell::{Cell, RefCell};
use std::collections::HashMap;
use std::rc::Rc;

use crate::hw::cluster::Cluster;
use crate::hw::node::Node;
use crate::sim::exec::Sim;
use crate::sim::time::SimTime;

/// 128-bit DAOS object id (hi = user bits, lo = sequence).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct Oid {
    pub hi: u64,
    pub lo: u64,
}

impl Oid {
    pub const ROOT_KV: Oid = Oid { hi: 0, lo: 0 };

    pub fn new(hi: u64, lo: u64) -> Oid {
        Oid { hi, lo }
    }

    /// Deterministic placement hash.
    pub(crate) fn place(&self) -> u64 {
        // splitmix-style avalanche of both words
        let mut z = self.hi ^ self.lo.rotate_left(32) ^ 0x9E37_79B9_7F4A_7C15;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
        z ^ (z >> 31)
    }
}

/// DAOS object class: redundancy/striping layout (thesis §3.1).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ObjClass {
    /// single target (FDB default; best for many small parallel objects)
    S1,
    /// striped over 2 targets
    S2,
    /// striped over all targets
    Sx,
    /// replicated on 2 targets (OC_RP_2G1)
    Rp2,
    /// erasure-coded 2 data + 1 parity (OC_EC_2P1G1)
    Ec2p1,
}

/// Per-op calibration for the engines.
#[derive(Clone, Copy, Debug)]
pub struct DaosCosts {
    /// client user-space per-op CPU
    pub client_op: SimTime,
    /// engine-side per-op service
    pub server_op: SimTime,
    /// pool connect / container open / create RPC handling
    pub pool_connect: SimTime,
    pub cont_open: SimTime,
    pub cont_create: SimTime,
    /// per-KV-entry media overhead (index maintenance in SCM/WAL)
    pub kv_entry_overhead: u64,
    /// VOS write-ahead-log commit latency for small ops — DAOS does not
    /// pay block-write latency for KiB-scale durable commits
    pub wal_commit: SimTime,
    /// byte-addressable read latency (indexed VOS extents / SCM)
    pub byte_read_lat: SimTime,
    /// ops at or below this size use the WAL/byte-addressable path
    pub small_op_threshold: u64,
}

impl Default for DaosCosts {
    fn default() -> Self {
        DaosCosts {
            client_op: SimTime::micros(2),
            server_op: SimTime::micros(5),
            pool_connect: SimTime::millis(2),
            cont_open: SimTime::micros(500),
            cont_create: SimTime::millis(5),
            kv_entry_overhead: 128,
            wal_commit: SimTime::micros(8),
            byte_read_lat: SimTime::micros(20),
            small_op_threshold: 256 << 10,
        }
    }
}

#[derive(Clone, Debug)]
pub struct DaosConfig {
    /// targets per engine (one engine per storage node here)
    pub targets_per_engine: usize,
    pub costs: DaosCosts,
}

impl Default for DaosConfig {
    fn default() -> Self {
        DaosConfig {
            targets_per_engine: 8,
            costs: DaosCosts::default(),
        }
    }
}

/// A storage target: a slice of an engine node's device.
pub(crate) struct Target {
    pub node: Rc<Node>,
}

pub(crate) struct KvObj {
    pub entries: HashMap<String, Vec<u8>>,
}

pub(crate) struct ArrayObj {
    pub data: crate::util::content::Content,
    /// recorded creation class (informational; access uses the handle's)
    #[allow(dead_code)]
    pub class: ObjClass,
}

/// A DAOS container: its own object address space.
pub struct Container {
    pub label: String,
    pub(crate) kvs: RefCell<HashMap<Oid, KvObj>>,
    pub(crate) arrays: RefCell<HashMap<Oid, ArrayObj>>,
    pub(crate) next_oid_lo: Cell<u64>,
}

/// A DAOS pool over all engine targets.
pub struct Pool {
    pub label: String,
    pub(crate) containers: RefCell<HashMap<String, Rc<Container>>>,
}

/// The deployed DAOS system.
pub struct Daos {
    pub sim: Sim,
    pub cluster: Rc<Cluster>,
    pub config: DaosConfig,
    pub(crate) targets: Vec<Target>,
    pub(crate) pools: RefCell<HashMap<String, Rc<Pool>>>,
    pub(crate) ops: Cell<u64>,
}

/// Client handle: caches pool/container connections like libdaos.
pub struct DaosClient {
    pub(crate) sys: Rc<Daos>,
    pub(crate) node: Rc<Node>,
    connected_pools: RefCell<HashMap<String, Rc<Pool>>>,
    open_conts: RefCell<HashMap<(String, String), Rc<Container>>>,
    /// pre-allocated OID range per container (batched alloc RPC)
    oid_cache: RefCell<HashMap<String, (u64, u64)>>,
    /// if true, all server/network costs are elided ("dummy libdaos",
    /// Fig 4.30 — measures pure client-side library overhead)
    pub dummy: bool,
}

impl Daos {
    pub fn deploy(sim: &Sim, cluster: &Rc<Cluster>, config: DaosConfig) -> Rc<Daos> {
        let mut targets = Vec::new();
        for node in cluster.storage_nodes() {
            for _ in 0..config.targets_per_engine {
                targets.push(Target { node: node.clone() });
            }
        }
        assert!(!targets.is_empty(), "daos needs storage nodes");
        Rc::new(Daos {
            sim: sim.clone(),
            cluster: cluster.clone(),
            config,
            targets,
            pools: RefCell::new(HashMap::new()),
            ops: Cell::new(0),
        })
    }

    /// Administrative pool creation (`dmg pool create`) — setup outside
    /// the measured window.
    pub fn create_pool(&self, label: &str) -> Rc<Pool> {
        let pool = Rc::new(Pool {
            label: label.to_string(),
            containers: RefCell::new(HashMap::new()),
        });
        self.pools
            .borrow_mut()
            .insert(label.to_string(), pool.clone());
        pool
    }

    pub fn client(self: &Rc<Self>, node: &Rc<Node>) -> DaosClient {
        DaosClient {
            sys: self.clone(),
            node: node.clone(),
            connected_pools: RefCell::new(HashMap::new()),
            open_conts: RefCell::new(HashMap::new()),
            oid_cache: RefCell::new(HashMap::new()),
            dummy: false,
        }
    }

    /// "dummy libdaos" client: all server/network costs elided (Fig 4.30).
    pub fn dummy_client(self: &Rc<Self>, node: &Rc<Node>) -> DaosClient {
        let mut c = self.client(node);
        c.dummy = true;
        c
    }

    pub fn total_ops(&self) -> u64 {
        self.ops.get()
    }

    pub fn target_count(&self) -> usize {
        self.targets.len()
    }

    /// Targets an object lands on for its class.
    pub(crate) fn targets_for(&self, oid: Oid, class: ObjClass) -> Vec<usize> {
        let n = self.targets.len();
        let first = (oid.place() % n as u64) as usize;
        let spread = |k: usize| -> Vec<usize> { (0..k.min(n)).map(|i| (first + i) % n).collect() };
        match class {
            ObjClass::S1 => spread(1),
            ObjClass::S2 => spread(2),
            ObjClass::Sx => spread(n),
            ObjClass::Rp2 => spread(2),
            ObjClass::Ec2p1 => spread(3),
        }
    }
}

impl DaosClient {
    /// A fresh client handle on the same system and node (own connection
    /// caches and OID batch) — the libdaos event-queue analogue backing
    /// the FDB per-request I/O sessions.
    pub fn fork(&self) -> DaosClient {
        let mut c = self.sys.client(&self.node);
        c.dummy = self.dummy;
        c
    }

    /// `daos_pool_connect`: one RPC; cached for the client lifetime.
    pub async fn pool_connect(&self, label: &str) -> Result<Rc<Pool>, DaosError> {
        if let Some(p) = self.connected_pools.borrow().get(label) {
            return Ok(p.clone());
        }
        if !self.dummy {
            self.sys.cluster.fabric.rpc_rtt(&self.sys.sim).await;
            self.sys
                .sim
                .sleep(self.sys.config.costs.pool_connect)
                .await;
        }
        let p = self
            .sys
            .pools
            .borrow()
            .get(label)
            .cloned()
            .ok_or(DaosError::NoSuchPool)?;
        self.connected_pools
            .borrow_mut()
            .insert(label.to_string(), p.clone());
        Ok(p)
    }

    /// `daos_cont_create_with_label`: atomic create-if-absent.
    pub async fn cont_create_with_label(
        &self,
        pool: &Rc<Pool>,
        label: &str,
    ) -> Result<Rc<Container>, DaosError> {
        if !self.dummy {
            self.sys.cluster.fabric.rpc_rtt(&self.sys.sim).await;
            self.sys.sim.sleep(self.sys.config.costs.cont_create).await;
        }
        let c = {
            let mut conts = pool.containers.borrow_mut();
            conts
                .entry(label.to_string())
                .or_insert_with(|| {
                    Rc::new(Container {
                        label: label.to_string(),
                        kvs: RefCell::new(HashMap::new()),
                        arrays: RefCell::new(HashMap::new()),
                        next_oid_lo: Cell::new(1),
                    })
                })
                .clone()
        };
        self.open_conts
            .borrow_mut()
            .insert((pool.label.clone(), label.to_string()), c.clone());
        Ok(c)
    }

    /// `daos_cont_open`: cached after first open. `Ok(None)` if missing.
    pub async fn cont_open(
        &self,
        pool: &Rc<Pool>,
        label: &str,
    ) -> Result<Option<Rc<Container>>, DaosError> {
        let key = (pool.label.clone(), label.to_string());
        if let Some(c) = self.open_conts.borrow().get(&key) {
            return Ok(Some(c.clone()));
        }
        if !self.dummy {
            self.sys.cluster.fabric.rpc_rtt(&self.sys.sim).await;
            self.sys.sim.sleep(self.sys.config.costs.cont_open).await;
        }
        let c = pool.containers.borrow().get(label).cloned();
        if let Some(ref c) = c {
            self.open_conts.borrow_mut().insert(key, c.clone());
        }
        Ok(c)
    }

    /// `daos_cont_destroy`: removes a dataset wholesale (thesis §3.1
    /// maintenance argument for container-per-dataset).
    pub fn cont_destroy(&self, pool: &Rc<Pool>, label: &str) -> bool {
        self.open_conts
            .borrow_mut()
            .remove(&(pool.label.clone(), label.to_string()));
        pool.containers.borrow_mut().remove(label).is_some()
    }

    /// `daos_cont_alloc_oids`: unique OIDs, one RPC per batch of 1024.
    pub async fn alloc_oid(&self, cont: &Rc<Container>) -> Oid {
        const BATCH: u64 = 1024;
        {
            let mut cache = self.oid_cache.borrow_mut();
            let slot = cache.entry(cont.label.clone()).or_insert((0, 0));
            if slot.0 < slot.1 {
                let lo = slot.0;
                slot.0 += 1;
                return Oid::new(1, lo);
            }
        }
        if !self.dummy {
            self.sys.cluster.fabric.rpc_rtt(&self.sys.sim).await;
        }
        let base = cont.next_oid_lo.get();
        cont.next_oid_lo.set(base + BATCH);
        let mut cache = self.oid_cache.borrow_mut();
        let slot = cache.entry(cont.label.clone()).or_insert((0, 0));
        *slot = (base + 1, base + BATCH);
        Oid::new(1, base)
    }

    /// Charge a server-side op with `bytes` payload against target `t`.
    pub(crate) async fn target_op(&self, t: usize, bytes: u64, write: bool) {
        self.sys.ops.set(self.sys.ops.get() + 1);
        let sim = &self.sys.sim;
        sim.sleep(self.sys.config.costs.client_op).await;
        if self.dummy {
            return;
        }
        let node = &self.sys.targets[t].node;
        let costs = &self.sys.config.costs;
        let small = bytes <= costs.small_op_threshold;
        if write {
            self.sys
                .cluster
                .fabric
                .xfer(sim, &self.node.nic, &node.nic, bytes)
                .await;
            node.cpu_serve(sim, costs.server_op).await;
            if small {
                // VOS WAL commit: log-structured, no block-write latency
                node.dev().write_with_lat(sim, bytes, costs.wal_commit).await;
            } else {
                node.dev().write(sim, bytes).await;
            }
        } else {
            self.sys.cluster.fabric.msg(sim).await;
            node.cpu_serve(sim, costs.server_op).await;
            if small {
                // byte-addressable indexed extent read
                node.dev()
                    .read_with_lat(sim, bytes, costs.byte_read_lat)
                    .await;
            } else {
                node.dev().read(sim, bytes).await;
            }
            self.sys
                .cluster
                .fabric
                .xfer(sim, &node.nic, &self.node.nic, bytes)
                .await;
        }
    }
}

/// DAOS error surface.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum DaosError {
    NoSuchPool,
    NoSuchContainer,
    NoSuchObject,
}

impl std::fmt::Display for DaosError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "{self:?}")
    }
}
impl std::error::Error for DaosError {}

#[cfg(test)]
pub(crate) mod testutil {
    use super::*;
    use crate::hw::profiles::{build_cluster, Testbed};

    pub fn small() -> (Sim, Rc<Daos>, Rc<Cluster>) {
        let sim = Sim::new();
        let cluster = Rc::new(build_cluster(Testbed::NextGenIo, 2, 2, false, false));
        let daos = Daos::deploy(&sim, &cluster, DaosConfig::default());
        (sim, daos, cluster)
    }
}

#[cfg(test)]
mod tests {
    use super::testutil::small;
    use super::*;

    #[test]
    fn deploy_targets() {
        let (_s, d, _c) = small();
        assert_eq!(d.target_count(), 16);
    }

    #[test]
    fn placement_is_deterministic_and_spread() {
        let (_s, d, _c) = small();
        let a = d.targets_for(Oid::new(1, 7), ObjClass::S1);
        let b = d.targets_for(Oid::new(1, 7), ObjClass::S1);
        assert_eq!(a, b);
        assert_eq!(a.len(), 1);
        assert_eq!(d.targets_for(Oid::new(1, 7), ObjClass::Sx).len(), 16);
        assert_eq!(d.targets_for(Oid::new(1, 7), ObjClass::Ec2p1).len(), 3);
        let mut seen = std::collections::HashSet::new();
        for lo in 0..64 {
            seen.insert(d.targets_for(Oid::new(1, lo), ObjClass::S1)[0]);
        }
        assert!(seen.len() > 8, "placement should spread: {}", seen.len());
    }

    #[test]
    fn pool_and_container_lifecycle() {
        let (sim, d, c) = small();
        d.create_pool("fdb");
        let node = c.client_nodes().next().unwrap().clone();
        let d2 = d.clone();
        sim.spawn(async move {
            let cli = d2.client(&node);
            let pool = cli.pool_connect("fdb").await.unwrap();
            assert!(cli.cont_open(&pool, "ds1").await.unwrap().is_none());
            let cont = cli.cont_create_with_label(&pool, "ds1").await.unwrap();
            assert_eq!(cont.label, "ds1");
            // racing create returns the same container
            let cont2 = cli.cont_create_with_label(&pool, "ds1").await.unwrap();
            assert!(Rc::ptr_eq(&cont, &cont2));
            assert!(cli.cont_destroy(&pool, "ds1"));
            assert!(cli.cont_open(&pool, "ds1").await.unwrap().is_none());
        });
        sim.run();
    }

    #[test]
    fn missing_pool_errors() {
        let (sim, d, c) = small();
        let node = c.client_nodes().next().unwrap().clone();
        sim.spawn(async move {
            let cli = d.client(&node);
            match cli.pool_connect("nope").await {
                Err(e) => assert_eq!(e, DaosError::NoSuchPool),
                Ok(_) => panic!("expected NoSuchPool"),
            }
        });
        sim.run();
    }

    #[test]
    fn oid_alloc_unique_and_batched() {
        let (sim, d, c) = small();
        d.create_pool("p");
        let node = c.client_nodes().next().unwrap().clone();
        sim.spawn(async move {
            let cli = d.client(&node);
            let pool = cli.pool_connect("p").await.unwrap();
            let cont = cli.cont_create_with_label(&pool, "c").await.unwrap();
            let mut seen = std::collections::HashSet::new();
            for _ in 0..3000 {
                assert!(seen.insert(cli.alloc_oid(&cont).await));
            }
        });
        sim.run();
    }

    #[test]
    fn dummy_client_is_near_free() {
        let (sim, d, c) = small();
        d.create_pool("p");
        let node = c.client_nodes().next().unwrap().clone();
        sim.spawn(async move {
            let cli = d.dummy_client(&node);
            let pool = cli.pool_connect("p").await.unwrap();
            let cont = cli.cont_create_with_label(&pool, "c").await.unwrap();
            for _ in 0..10 {
                cli.alloc_oid(&cont).await;
            }
        });
        let end = sim.run();
        // only client-op sleeps, far below any real network cost
        assert!(end < SimTime::micros(100), "dummy end {end}");
    }
}
