//! DAOS array API (`daos_array_*`): bulk 1-D byte arrays (thesis Fig 2.1).
//!
//! `daos_array_open_with_attr` issues no RPC (the optimisation thesis
//! §3.1.1 found critical at scale); writes/reads hit the target(s)
//! chosen by the object class. Striped classes split transfers across
//! targets concurrently; replicated classes write all replicas before
//! returning; EC classes write data+parity chunks.

use std::rc::Rc;

use super::{ArrayObj, Container, DaosClient, DaosError, ObjClass, Oid};
use crate::sim::futures::{boxed, join_all};
use crate::util::content::Bytes;

/// An opened array object.
pub struct ArrayHandle {
    pub oid: Oid,
    pub class: ObjClass,
    cont: Rc<Container>,
}

impl DaosClient {
    /// `daos_array_open_with_attr`: no RPC, never fails.
    pub fn array_open_with_attr(
        &self,
        cont: &Rc<Container>,
        oid: Oid,
        class: ObjClass,
    ) -> ArrayHandle {
        ArrayHandle {
            oid,
            class,
            cont: cont.clone(),
        }
    }

    /// `daos_array_write` at `offset` (real-bytes convenience).
    pub async fn array_write(&self, arr: &ArrayHandle, offset: u64, data: &[u8]) {
        self.array_write_data(arr, offset, Bytes::real(data.to_vec()))
            .await
    }

    /// `daos_array_write` of a (possibly virtual) byte string.
    pub async fn array_write_data(&self, arr: &ArrayHandle, offset: u64, data: Bytes) {
        let targets = self.sys.targets_for(arr.oid, arr.class);
        let total = data.len();
        // time charge per class
        match arr.class {
            ObjClass::S1 => {
                self.target_op(targets[0], total, true).await;
            }
            ObjClass::S2 | ObjClass::Sx => {
                // stripe: split bytes evenly over targets, concurrent
                let k = targets.len() as u64;
                let futs = targets
                    .iter()
                    .enumerate()
                    .map(|(i, &t)| {
                        let chunk = total / k + if (i as u64) < total % k { 1 } else { 0 };
                        boxed(async move {
                            if chunk > 0 {
                                self.target_op(t, chunk, true).await;
                            }
                        })
                    })
                    .collect();
                join_all(futs).await;
            }
            ObjClass::Rp2 => {
                // both replicas written before returning
                let futs = targets
                    .iter()
                    .map(|&t| boxed(async move { self.target_op(t, total, true).await }))
                    .collect();
                join_all(futs).await;
            }
            ObjClass::Ec2p1 => {
                // 2 data chunks + 1 parity chunk of total/2 each
                let chunk = total.div_ceil(2);
                let futs = targets
                    .iter()
                    .map(|&t| boxed(async move { self.target_op(t, chunk, true).await }))
                    .collect();
                join_all(futs).await;
            }
        }
        // commit content
        let mut arrays = arr.cont.arrays.borrow_mut();
        let obj = arrays.entry(arr.oid).or_insert_with(|| ArrayObj {
            data: crate::util::content::Content::new(),
            class: arr.class,
        });
        obj.data.write(offset, data);
    }

    /// `daos_array_read`: byte range `[offset, offset+len)`. Does not fail
    /// on over-reads (mirrors libdaos) — returns the available bytes.
    pub async fn array_read(
        &self,
        arr: &ArrayHandle,
        offset: u64,
        len: u64,
    ) -> Result<Bytes, DaosError> {
        let data = {
            let arrays = arr.cont.arrays.borrow();
            let obj = arrays.get(&arr.oid).ok_or(DaosError::NoSuchObject)?;
            let end = (offset + len).min(obj.data.len());
            let start = offset.min(end);
            obj.data.read(start, end - start)
        };
        let total = data.len();
        let targets = self.sys.targets_for(arr.oid, arr.class);
        match arr.class {
            ObjClass::S1 | ObjClass::Rp2 => {
                // read from one (primary) target; DAOS is byte-addressable
                self.target_op(targets[0], total, false).await;
            }
            ObjClass::S2 | ObjClass::Sx => {
                let k = targets.len() as u64;
                let futs = targets
                    .iter()
                    .enumerate()
                    .map(|(i, &t)| {
                        let chunk = total / k + if (i as u64) < total % k { 1 } else { 0 };
                        boxed(async move {
                            if chunk > 0 {
                                self.target_op(t, chunk, false).await;
                            }
                        })
                    })
                    .collect();
                join_all(futs).await;
            }
            ObjClass::Ec2p1 => {
                // read the 2 data chunks
                let chunk = total.div_ceil(2);
                let futs = targets[..2]
                    .iter()
                    .map(|&t| boxed(async move { self.target_op(t, chunk, false).await }))
                    .collect();
                join_all(futs).await;
            }
        }
        Ok(data)
    }

    /// `daos_array_get_size` — a full RPC (the call the thesis found worth
    /// eliminating by encoding lengths in location descriptors).
    pub async fn array_get_size(&self, arr: &ArrayHandle) -> Result<u64, DaosError> {
        let targets = self.sys.targets_for(arr.oid, arr.class);
        self.target_op(targets[0], 64, false).await;
        let arrays = arr.cont.arrays.borrow();
        arrays
            .get(&arr.oid)
            .map(|o| o.data.len())
            .ok_or(DaosError::NoSuchObject)
    }
}

#[cfg(test)]
mod tests {
    use super::super::testutil::small;
    use super::*;
    use crate::sim::time::SimTime;
    use std::cell::Cell;

    fn with_client<F, Fut>(f: F) -> SimTime
    where
        F: FnOnce(DaosClient, Rc<Container>) -> Fut + 'static,
        Fut: std::future::Future<Output = ()> + 'static,
    {
        let (sim, d, c) = small();
        d.create_pool("p");
        let node = c.client_nodes().next().unwrap().clone();
        sim.spawn(async move {
            let cli = d.client(&node);
            let pool = cli.pool_connect("p").await.unwrap();
            let cont = cli.cont_create_with_label(&pool, "c").await.unwrap();
            f(cli, cont).await;
        });
        sim.run()
    }

    #[test]
    fn write_read_roundtrip() {
        with_client(|cli, cont| async move {
            let arr = cli.array_open_with_attr(&cont, Oid::new(1, 1), ObjClass::S1);
            cli.array_write(&arr, 0, b"weather-field-bytes").await;
            let got = cli.array_read(&arr, 0, 19).await.unwrap().to_vec();
            assert_eq!(&got, b"weather-field-bytes");
            assert_eq!(cli.array_get_size(&arr).await.unwrap(), 19);
        });
    }

    #[test]
    fn partial_range_read() {
        with_client(|cli, cont| async move {
            let arr = cli.array_open_with_attr(&cont, Oid::new(1, 2), ObjClass::S1);
            cli.array_write(&arr, 0, b"0123456789").await;
            let got = cli.array_read(&arr, 3, 4).await.unwrap().to_vec();
            assert_eq!(&got, b"3456");
        });
    }

    #[test]
    fn overread_returns_available() {
        with_client(|cli, cont| async move {
            let arr = cli.array_open_with_attr(&cont, Oid::new(1, 3), ObjClass::S1);
            cli.array_write(&arr, 0, b"abc").await;
            let got = cli.array_read(&arr, 0, 100).await.unwrap().to_vec();
            assert_eq!(&got, b"abc");
        });
    }

    #[test]
    fn missing_array_errors() {
        with_client(|cli, cont| async move {
            let arr = cli.array_open_with_attr(&cont, Oid::new(9, 9), ObjClass::S1);
            assert_eq!(
                cli.array_read(&arr, 0, 1).await.unwrap_err(),
                DaosError::NoSuchObject
            );
        });
    }

    #[test]
    fn replication_doubles_write_cost() {
        let t_s1 = with_client(|cli, cont| async move {
            let arr = cli.array_open_with_attr(&cont, Oid::new(1, 4), ObjClass::S1);
            for _ in 0..50 {
                cli.array_write(&arr, 0, &vec![0u8; 1 << 20]).await;
            }
        });
        let t_rp2 = with_client(|cli, cont| async move {
            let arr = cli.array_open_with_attr(&cont, Oid::new(1, 4), ObjClass::Rp2);
            for _ in 0..50 {
                cli.array_write(&arr, 0, &vec![0u8; 1 << 20]).await;
            }
        });
        // > 1.2x: replica writes overlap across targets, and ~7 ms of
        // pool/container setup is common to both runs.
        assert!(
            t_rp2.as_nanos() > (t_s1.as_nanos() as f64 * 1.2) as u64,
            "rp2 {t_rp2} vs s1 {t_s1}"
        );
    }

    #[test]
    fn sx_striping_spreads_one_large_write() {
        // one big array: SX should beat S1 on a single stream
        let t_s1 = with_client(|cli, cont| async move {
            let arr = cli.array_open_with_attr(&cont, Oid::new(1, 5), ObjClass::S1);
            cli.array_write(&arr, 0, &vec![0u8; 64 << 20]).await;
        });
        let t_sx = with_client(|cli, cont| async move {
            let arr = cli.array_open_with_attr(&cont, Oid::new(1, 5), ObjClass::Sx);
            cli.array_write(&arr, 0, &vec![0u8; 64 << 20]).await;
        });
        assert!(t_sx < t_s1, "sx {t_sx} vs s1 {t_s1}");
        let _ = Cell::new(0); // silence unused import on some cfgs
    }
}
