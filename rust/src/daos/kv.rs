//! DAOS high-level key-value API (`daos_kv_*`): transactional put/get/
//! list/remove on a single-key dictionary object (thesis Fig 2.1).
//!
//! MVCC semantics: a put is durable and visible on return; concurrent
//! readers never see partial values. There is no lock traffic — contended
//! access costs only server queueing.

use std::rc::Rc;

use super::{Container, DaosClient, DaosError, ObjClass, Oid};

/// An opened KV object (`daos_kv_open` issues no RPC — objects always
/// "exist"; content appears on first put).
pub struct KvHandle {
    pub oid: Oid,
    pub class: ObjClass,
    cont: Rc<Container>,
}

impl DaosClient {
    /// `daos_kv_open`: no RPC, cannot fail.
    pub fn kv_open(&self, cont: &Rc<Container>, oid: Oid, class: ObjClass) -> KvHandle {
        KvHandle {
            oid,
            class,
            cont: cont.clone(),
        }
    }

    /// `daos_kv_put`: transactional insert/overwrite of one entry.
    pub async fn kv_put(&self, kv: &KvHandle, key: &str, value: &[u8]) {
        let t = self.sys.targets_for(kv.oid, kv.class)[0];
        let bytes = key.len() as u64 + value.len() as u64 + self.sys.config.costs.kv_entry_overhead;
        self.target_op(t, bytes, true).await;
        kv.cont
            .kvs
            .borrow_mut()
            .entry(kv.oid)
            .or_insert_with(|| super::KvObj {
                entries: std::collections::HashMap::new(),
            })
            .entries
            .insert(key.to_string(), value.to_vec());
    }

    /// `daos_kv_get`: `Ok(None)` when the key is absent.
    pub async fn kv_get(&self, kv: &KvHandle, key: &str) -> Result<Option<Vec<u8>>, DaosError> {
        let t = self.sys.targets_for(kv.oid, kv.class)[0];
        let value = kv
            .cont
            .kvs
            .borrow()
            .get(&kv.oid)
            .and_then(|o| o.entries.get(key).cloned());
        let bytes = value.as_ref().map(|v| v.len() as u64).unwrap_or(0)
            + key.len() as u64
            + self.sys.config.costs.kv_entry_overhead;
        self.target_op(t, bytes, false).await;
        Ok(value)
    }

    /// `daos_kv_list`: enumerate keys. DAOS pages key listings — one RPC
    /// round per 2048 keys (values are NOT returned, unlike RADOS omaps;
    /// thesis §3.2.1 notes this costs the DAOS `list()` extra gets).
    pub async fn kv_list(&self, kv: &KvHandle) -> Vec<String> {
        let keys: Vec<String> = kv
            .cont
            .kvs
            .borrow()
            .get(&kv.oid)
            .map(|o| o.entries.keys().cloned().collect())
            .unwrap_or_default();
        let t = self.sys.targets_for(kv.oid, kv.class)[0];
        let rounds = (keys.len() / 2048) + 1;
        for _ in 0..rounds {
            let payload: u64 = 32 * 2048.min(keys.len().max(1)) as u64;
            self.target_op(t, payload, false).await;
        }
        keys
    }

    /// `daos_kv_remove`.
    pub async fn kv_remove(&self, kv: &KvHandle, key: &str) {
        let t = self.sys.targets_for(kv.oid, kv.class)[0];
        self.target_op(t, key.len() as u64 + 64, true).await;
        if let Some(o) = kv.cont.kvs.borrow_mut().get_mut(&kv.oid) {
            o.entries.remove(key);
        }
    }

    /// Entry count without timing (test/verification helper).
    pub fn kv_len(&self, kv: &KvHandle) -> usize {
        kv.cont
            .kvs
            .borrow()
            .get(&kv.oid)
            .map(|o| o.entries.len())
            .unwrap_or(0)
    }
}

#[cfg(test)]
mod tests {
    use super::super::testutil::small;
    use super::*;

    #[test]
    fn put_get_roundtrip() {
        let (sim, d, c) = small();
        d.create_pool("p");
        let node = c.client_nodes().next().unwrap().clone();
        sim.spawn(async move {
            let cli = d.client(&node);
            let pool = cli.pool_connect("p").await.unwrap();
            let cont = cli.cont_create_with_label(&pool, "c").await.unwrap();
            let kv = cli.kv_open(&cont, Oid::ROOT_KV, ObjClass::S1);
            cli.kv_put(&kv, "step=1", b"loc-a").await;
            assert_eq!(
                cli.kv_get(&kv, "step=1").await.unwrap().as_deref(),
                Some(b"loc-a".as_ref())
            );
            assert_eq!(cli.kv_get(&kv, "step=2").await.unwrap(), None);
        });
        sim.run();
    }

    #[test]
    fn overwrite_replaces_value_transactionally() {
        let (sim, d, c) = small();
        d.create_pool("p");
        let node = c.client_nodes().next().unwrap().clone();
        sim.spawn(async move {
            let cli = d.client(&node);
            let pool = cli.pool_connect("p").await.unwrap();
            let cont = cli.cont_create_with_label(&pool, "c").await.unwrap();
            let kv = cli.kv_open(&cont, Oid::ROOT_KV, ObjClass::S1);
            cli.kv_put(&kv, "k", b"v1").await;
            cli.kv_put(&kv, "k", b"v2").await;
            assert_eq!(
                cli.kv_get(&kv, "k").await.unwrap().as_deref(),
                Some(b"v2".as_ref())
            );
            assert_eq!(cli.kv_len(&kv), 1);
        });
        sim.run();
    }

    #[test]
    fn list_and_remove() {
        let (sim, d, c) = small();
        d.create_pool("p");
        let node = c.client_nodes().next().unwrap().clone();
        sim.spawn(async move {
            let cli = d.client(&node);
            let pool = cli.pool_connect("p").await.unwrap();
            let cont = cli.cont_create_with_label(&pool, "c").await.unwrap();
            let kv = cli.kv_open(&cont, Oid::new(2, 9), ObjClass::S1);
            for i in 0..10 {
                cli.kv_put(&kv, &format!("k{i}"), b"x").await;
            }
            let mut keys = cli.kv_list(&kv).await;
            keys.sort();
            assert_eq!(keys.len(), 10);
            assert_eq!(keys[0], "k0");
            cli.kv_remove(&kv, "k0").await;
            assert_eq!(cli.kv_len(&kv), 9);
        });
        sim.run();
    }

    #[test]
    fn cross_client_visibility_immediate() {
        let (sim, d, c) = small();
        d.create_pool("p");
        let writer_node = c.client_nodes().next().unwrap().clone();
        let reader_node = c.client_nodes().nth(1).unwrap().clone();
        let d2 = d.clone();
        sim.spawn(async move {
            let w = d2.client(&writer_node);
            let pool = w.pool_connect("p").await.unwrap();
            let cont = w.cont_create_with_label(&pool, "c").await.unwrap();
            let kv = w.kv_open(&cont, Oid::ROOT_KV, ObjClass::S1);
            w.kv_put(&kv, "shared", b"now-visible").await;
            // a different client sees it immediately (no flush needed)
            let r = d2.client(&reader_node);
            let pool_r = r.pool_connect("p").await.unwrap();
            let cont_r = r.cont_open(&pool_r, "c").await.unwrap().unwrap();
            let kv_r = r.kv_open(&cont_r, Oid::ROOT_KV, ObjClass::S1);
            assert_eq!(
                r.kv_get(&kv_r, "shared").await.unwrap().as_deref(),
                Some(b"now-visible".as_ref())
            );
        });
        sim.run();
    }
}
