//! # fdb-rs
//!
//! Reproduction of *"Exploring Novel Data Storage Approaches for
//! Large-Scale Numerical Weather Prediction"* (Manubens Gil, 2025).
//!
//! The crate contains, bottom-up:
//!
//! * [`util`] — self-contained replacements for crates unavailable in the
//!   offline build (PRNG, CLI parsing, JSON, property testing, stats).
//! * [`sim`] — a deterministic single-threaded virtual-time async executor
//!   (the discrete-event engine), timed FIFO resources, and per-op-class
//!   trace accounting.
//! * [`hw`] — hardware models: SCM/NVMe devices, NICs, PSM2/TCP fabrics,
//!   nodes, clusters, and the NEXTGenIO / GCP testbed profiles.
//! * [`lustre`], [`daos`], [`ceph`], [`s3`] — the storage substrates the
//!   thesis evaluates, implemented as faithful behavioural simulators
//!   (real data + real index structures, virtual time).
//! * [`fdb`] — the FDB meteorological object store: schema-driven keys,
//!   the object-safe [`fdb::Store`] / [`fdb::Catalogue`] backend traits
//!   with POSIX, DAOS, Ceph/RADOS, S3 and Null implementations
//!   (Chapters 2–3), declarative construction via [`fdb::FdbBuilder`] /
//!   [`fdb::BackendConfig`], and the batched `archive_many` /
//!   `retrieve_many` paths that pipeline catalogue lookups with store
//!   reads. An [`fdb::IoProfile`] (builder `io_depth`, CLI
//!   `--io-depth`) turns the batched paths into a queue-depth engine:
//!   per-request client sessions ([`fdb::StoreSession`]) keep up to N
//!   store reads/writes in flight behind a sim-native semaphore, with
//!   results re-ordered to input order — byte-identical at every depth.
//! * [`bench`] — IOR-like, Field I/O, and fdb-hammer workload generators
//!   plus the scenario registry that regenerates every evaluation figure.
//! * [`workflow`] — the operational NWP I/O pattern: I/O servers, flush
//!   barriers, staggered PGEN jobs.
//! * [`runtime`] — PJRT execution of the AOT-compiled JAX/Pallas PGEN
//!   artifacts (`artifacts/*.hlo.txt`); python never runs at request time.
//! * [`coordinator`] — the leader that wires configs, clusters, workloads
//!   and the runtime together behind the `fdbctl` CLI.

pub mod util {
    pub mod cli;
    pub mod content;
    pub mod humansize;
    pub mod json;
    pub mod prop;
    pub mod rng;
    pub mod stats;
}

pub mod sim {
    pub mod exec;
    pub mod futures;
    pub mod resource;
    pub mod time;
    pub mod trace;
}

pub mod hw {
    pub mod cluster;
    pub mod device;
    pub mod fabric;
    pub mod node;
    pub mod profiles;
}

pub mod lustre;
pub mod daos;
pub mod ceph;
pub mod s3;
pub mod fdb;
pub mod bench;
pub mod workflow;
pub mod runtime;
pub mod coordinator;
