//! PJRT runtime: load the AOT-compiled JAX/Pallas artifacts
//! (`artifacts/*.hlo.txt`) and execute them from the L3 hot path.
//! Python never runs here — the interchange is HLO text (see
//! `python/compile/aot.py` and /opt/xla-example/README.md).

use std::collections::HashMap;
use std::rc::Rc;

use anyhow::{Context, Result};

use crate::sim::time::SimTime;
use crate::workflow::PgenCompute;

/// Locates artifact files. `FDB_ARTIFACTS` overrides the default dir.
pub fn artifacts_dir() -> std::path::PathBuf {
    std::env::var("FDB_ARTIFACTS")
        .map(std::path::PathBuf::from)
        .unwrap_or_else(|_| {
            // crate root: next to Cargo.toml
            std::path::PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts")
        })
}

/// A PJRT CPU client with a cache of compiled executables.
pub struct PjrtRuntime {
    client: xla::PjRtClient,
    execs: std::cell::RefCell<HashMap<String, Rc<xla::PjRtLoadedExecutable>>>,
    dir: std::path::PathBuf,
}

impl PjrtRuntime {
    pub fn cpu() -> Result<Rc<PjrtRuntime>> {
        Ok(Rc::new(PjrtRuntime {
            client: xla::PjRtClient::cpu().context("PJRT CPU client")?,
            execs: std::cell::RefCell::new(HashMap::new()),
            dir: artifacts_dir(),
        }))
    }

    pub fn with_dir(dir: impl Into<std::path::PathBuf>) -> Result<Rc<PjrtRuntime>> {
        Ok(Rc::new(PjrtRuntime {
            client: xla::PjRtClient::cpu().context("PJRT CPU client")?,
            execs: std::cell::RefCell::new(HashMap::new()),
            dir: dir.into(),
        }))
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Load + compile `<name>.hlo.txt` (cached).
    pub fn load(&self, name: &str) -> Result<Rc<xla::PjRtLoadedExecutable>> {
        if let Some(e) = self.execs.borrow().get(name) {
            return Ok(e.clone());
        }
        let path = self.dir.join(format!("{name}.hlo.txt"));
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str().context("artifact path utf8")?,
        )
        .with_context(|| format!("parse {path:?} — run `make artifacts`"))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = Rc::new(self.client.compile(&comp).context("pjrt compile")?);
        self.execs
            .borrow_mut()
            .insert(name.to_string(), exe.clone());
        Ok(exe)
    }

    /// Execute with f32 inputs of the given shapes; returns flat f32
    /// outputs (the jax export wraps results in a 1-tuple).
    pub fn run_f32(
        &self,
        exe: &xla::PjRtLoadedExecutable,
        inputs: &[(&[f32], &[i64])],
    ) -> Result<Vec<f32>> {
        let mut literals = Vec::with_capacity(inputs.len());
        for (data, dims) in inputs {
            let lit = if dims.is_empty() {
                xla::Literal::scalar(data[0])
            } else {
                xla::Literal::vec1(data).reshape(dims)?
            };
            literals.push(lit);
        }
        let result = exe.execute::<xla::Literal>(&literals)?[0][0].to_literal_sync()?;
        let out = result.to_tuple1()?;
        Ok(out.to_vec::<f32>()?)
    }
}

/// PGEN product generation via the AOT `pgen_e{E}_g{G}` artifact.
pub struct PgenPipeline {
    runtime: Rc<PjrtRuntime>,
    exe: Rc<xla::PjRtLoadedExecutable>,
    pub ensemble: usize,
    pub grid: usize,
    pub threshold: f32,
    /// virtual-time cost per executed group, charged to the simulation
    pub group_cost: SimTime,
    invocations: std::cell::Cell<u64>,
}

impl PgenPipeline {
    pub fn new(runtime: &Rc<PjrtRuntime>, ensemble: usize, grid: usize) -> Result<PgenPipeline> {
        let exe = runtime.load(&format!("pgen_e{ensemble}_g{grid}"))?;
        Ok(PgenPipeline {
            runtime: runtime.clone(),
            exe,
            ensemble,
            grid,
            threshold: 15.0,
            group_cost: SimTime::millis(2),
            invocations: std::cell::Cell::new(0),
        })
    }

    pub fn invocations(&self) -> u64 {
        self.invocations.get()
    }

    /// Run one ensemble group `[E, G, G]` (flat) → `[3, G, G]` (flat).
    pub fn run_group(&self, ens_flat: &[f32]) -> Result<Vec<f32>> {
        let g = self.grid as i64;
        self.invocations.set(self.invocations.get() + 1);
        self.runtime.run_f32(
            &self.exe,
            &[
                (ens_flat, &[self.ensemble as i64, g, g]),
                (&[self.threshold], &[]),
            ],
        )
    }
}

impl PgenCompute for PgenPipeline {
    fn run(&self, fields: &[Vec<f32>]) -> Vec<Vec<f32>> {
        let gg = self.grid * self.grid;
        let mut products = Vec::new();
        // groups of E fields; the tail group pads by repeating the last
        for group in fields.chunks(self.ensemble) {
            let mut flat = Vec::with_capacity(self.ensemble * gg);
            for f in group {
                assert_eq!(f.len(), gg, "field grid mismatch");
                flat.extend_from_slice(f);
            }
            while flat.len() < self.ensemble * gg {
                let last = group.last().expect("non-empty group");
                flat.extend_from_slice(last);
            }
            let out = self
                .run_group(&flat)
                .expect("pgen artifact execution failed");
            // split [3, G, G] into three products
            for p in 0..3 {
                products.push(out[p * gg..(p + 1) * gg].to_vec());
            }
        }
        products
    }

    fn cost(&self) -> SimTime {
        self.group_cost
    }
}

/// The synthetic model integrator via the `model_step_g{G}` artifact.
pub struct ModelStepper {
    runtime: Rc<PjrtRuntime>,
    exe: Rc<xla::PjRtLoadedExecutable>,
    pub grid: usize,
}

impl ModelStepper {
    pub fn new(runtime: &Rc<PjrtRuntime>, grid: usize) -> Result<ModelStepper> {
        let exe = runtime.load(&format!("model_step_g{grid}"))?;
        Ok(ModelStepper {
            runtime: runtime.clone(),
            exe,
            grid,
        })
    }

    pub fn step(&self, state: &[f32], noise: &[f32]) -> Result<Vec<f32>> {
        let g = self.grid as i64;
        self.runtime
            .run_f32(&self.exe, &[(state, &[g, g]), (noise, &[g, g])])
    }
}

/// The codec roundtrip via the `codec_g{G}` artifact (store-side path).
pub struct Codec {
    runtime: Rc<PjrtRuntime>,
    exe: Rc<xla::PjRtLoadedExecutable>,
    pub grid: usize,
}

impl Codec {
    pub fn new(runtime: &Rc<PjrtRuntime>, grid: usize) -> Result<Codec> {
        let exe = runtime.load(&format!("codec_g{grid}"))?;
        Ok(Codec {
            runtime: runtime.clone(),
            exe,
            grid,
        })
    }

    pub fn roundtrip(&self, field: &[f32]) -> Result<Vec<f32>> {
        let g = self.grid as i64;
        self.runtime.run_f32(&self.exe, &[(field, &[g, g])])
    }
}
