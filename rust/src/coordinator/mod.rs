//! The leader/coordinator: wires configuration → deployed simulated
//! cluster → workload → report, behind the `fdbctl` CLI and examples.

use std::rc::Rc;

use anyhow::{bail, Result};

use crate::bench::scenario::{deploy, Deployment, RedundancyOpt, SystemKind, WrapperOpt};
use crate::bench::{fieldio, hammer, ior};
use crate::fdb::wrappers::ReadPolicy;
use crate::fdb::{MetricsRegistry, ResilienceProfile};
use crate::hw::profiles::Testbed;
use crate::runtime::{PgenPipeline, PjrtRuntime};
use crate::util::cli::Args;
use crate::workflow::driver::{self, OperationalConfig};
use crate::workflow::{Compute, NullCompute};

pub fn parse_testbed(s: &str) -> Result<Testbed> {
    Ok(match s {
        "nextgenio" | "ngio" => Testbed::NextGenIo,
        "gcp" => Testbed::Gcp,
        other => bail!("unknown testbed `{other}` (nextgenio|gcp)"),
    })
}

pub fn parse_system(s: &str) -> Result<SystemKind> {
    Ok(match s {
        "lustre" | "posix" => SystemKind::Lustre,
        "daos" => SystemKind::Daos,
        "ceph" | "rados" => SystemKind::Ceph,
        "null" => SystemKind::Null,
        other => bail!("unknown system `{other}` (lustre|daos|ceph|null)"),
    })
}

/// `none | tiered | replicated[:n] | sharded[:n]` → a composable
/// backend wrapper layered over the system's base backend.
pub fn parse_wrapper(s: &str) -> Result<WrapperOpt> {
    let (name, n) = match s.split_once(':') {
        Some((name, n)) => (
            name,
            Some(n.parse::<usize>().map_err(|_| {
                anyhow::anyhow!("wrapper count in `{s}` must be a positive integer")
            })?),
        ),
        None => (s, None),
    };
    if n == Some(0) {
        bail!("wrapper count in `{s}` must be >= 1");
    }
    Ok(match name {
        "none" | "bare" | "tiered" => {
            if n.is_some() {
                bail!("wrapper `{name}` takes no count (got `{s}`)");
            }
            if name == "tiered" {
                WrapperOpt::Tiered
            } else {
                WrapperOpt::Bare
            }
        }
        "replicated" => WrapperOpt::Replicated(n.unwrap_or(2)),
        "sharded" => WrapperOpt::Sharded(n.unwrap_or(4)),
        other => bail!("unknown wrapper `{other}` (none|tiered|replicated[:n]|sharded[:n])"),
    })
}

/// `first|rr|fastest` → how a replicated store routes reads.
pub fn parse_read_policy(s: &str) -> Result<ReadPolicy> {
    Ok(match s {
        "first" | "first-healthy" => ReadPolicy::FirstHealthy,
        "rr" | "round-robin" => ReadPolicy::RoundRobin,
        "fastest" => ReadPolicy::Fastest,
        other => bail!("unknown read policy `{other}` (first|rr|fastest)"),
    })
}

/// A value-taking CLI option with a default; a dangling `--name` (no
/// value) is a usage error rather than a silent fallback.
fn opt<'a>(args: &'a Args, name: &str, default: &'a str) -> Result<&'a str> {
    args.value_of(name)
        .map(|v| v.unwrap_or(default))
        .map_err(|e| anyhow::anyhow!(e))
}

/// Numeric option with a default; a dangling flag or an unparseable
/// value is a usage error rather than a silent default.
fn num<T: std::str::FromStr>(args: &Args, name: &str, default: T) -> Result<T> {
    args.parsed_or(name, default).map_err(|e| anyhow::anyhow!(e))
}

/// Byte-size option (unit suffixes allowed) with the same strictness.
fn size(args: &Args, name: &str, default: u64) -> Result<u64> {
    args.bytes_of(name, default).map_err(|e| anyhow::anyhow!(e))
}

/// `--io-depth N` or `--io-depth auto` (auto = the system's
/// device-parallelism profile, [`SystemKind::auto_io_depth`]).
fn parse_io_depth(args: &Args, kind: SystemKind) -> Result<usize> {
    let raw = opt(args, "io-depth", "1")?;
    if raw == "auto" {
        return Ok(kind.auto_io_depth());
    }
    raw.parse::<usize>()
        .map_err(|_| anyhow::anyhow!("--io-depth must be a number or `auto` (got `{raw}`)"))
}

/// The resilience flags shared by `hammer`, `opsrun`, `crash`, and
/// `degrade`: `--retry <attempts>` (total attempts, 1 = off),
/// `--retry-backoff-us <us>` (exponential base), `--op-deadline-us
/// <us>` (0 = off), `--hedge-us <us>` (0 = off), `--quarantine-after
/// <n>` (0 = off), `--quarantine-backoff-us <us>`. Returns `None` when
/// every knob sits at its no-op default.
fn parse_resilience(args: &Args) -> Result<Option<ResilienceProfile>> {
    let res = ResilienceProfile::retries(num(args, "retry", 1u32)?)
        .with_backoff_us(num(args, "retry-backoff-us", 200u64)?)
        .with_op_deadline_us(num(args, "op-deadline-us", 0u64)?)
        .with_hedge_us(num(args, "hedge-us", 0u64)?)
        .with_quarantine(
            num(args, "quarantine-after", 0u32)?,
            num(args, "quarantine-backoff-us", 10_000u64)?,
        );
    res.validate()
        .map_err(|e| anyhow::anyhow!("--retry/--op-deadline-us/--hedge-us: {e}"))?;
    Ok(res.enabled().then_some(res))
}

/// Shared fdb-hammer workload setup for `hammer`, `trace`, and
/// `metrics`: parse the deployment + workload options and attach the
/// telemetry registry when one is given.
fn hammer_workload(
    args: &Args,
    reg: Option<&MetricsRegistry>,
) -> Result<(Deployment, hammer::HammerConfig)> {
    let testbed = parse_testbed(opt(args, "testbed", "gcp")?)?;
    let kind = parse_system(opt(args, "system", "daos")?)?;
    let wrapper = parse_wrapper(opt(args, "wrapper", "none")?)?;
    let servers = num(args, "servers", 4usize)?;
    let clients = num(args, "clients", 8usize)?;
    let io = crate::fdb::IoProfile::depth(parse_io_depth(args, kind)?)
        .with_preload_indexes(args.flag("index-cache"))
        .with_coalesce_gap(size(args, "coalesce-gap", 0)?)
        .with_coalesce_max(size(
            args,
            "coalesce-max",
            crate::fdb::IoProfile::DEFAULT_COALESCE_MAX,
        )?)
        .with_durable(args.flag("durable"))
        .with_slow_op_us(num(args, "slow-op-us", 0u64)?);
    io.validate().map_err(|e| anyhow::anyhow!("--io-depth/--coalesce-*: {e}"))?;
    // seeded fault injection: the plan wraps the base backend, inside
    // any composable wrapper, so replica/shard/tier failure paths run
    let fault = args
        .value_of("fault")
        .map_err(|e| anyhow::anyhow!(e))?
        .map(crate::fdb::FaultPlan::parse)
        .transpose()
        .map_err(|e| anyhow::anyhow!("--fault: {e}"))?;
    let mut dep = deploy(testbed, kind, servers, clients, RedundancyOpt::None)
        .with_wrapper(wrapper)
        .with_io(io);
    let faults_ok = fault.is_some();
    if let Some(plan) = fault {
        dep = dep.with_fault(plan);
    }
    if let Some(policy) = args.value_of("read-policy").map_err(|e| anyhow::anyhow!(e))? {
        dep = dep.with_read_policy(parse_read_policy(policy)?);
    }
    if let Some(res) = parse_resilience(args)? {
        dep = dep.with_resilience(res);
    }
    if let Some(reg) = reg {
        dep = dep.with_metrics(reg);
    }
    let cfg = hammer::HammerConfig {
        procs_per_node: num(args, "procs", 8usize)?,
        nsteps: num(args, "steps", 10u32)?,
        nparams: num(args, "params", 5u32)?,
        nlevels: num(args, "levels", 4u32)?,
        field_size: size(args, "field-size", 1 << 20)?,
        check: args.flag("check"),
        contention: args.flag("contention"),
        faults_ok,
    };
    Ok((dep, cfg))
}

/// Dump a registry as the machine-readable metrics record (`--metrics
/// <path>` on `hammer`/`opsrun`/`crash`).
fn write_metrics_json(reg: &MetricsRegistry, path: &str) -> Result<()> {
    std::fs::write(path, format!("{}", reg.to_json()))
        .map_err(|e| anyhow::anyhow!("write {path}: {e}"))?;
    println!("wrote {path}");
    Ok(())
}

/// Print the slow-op log a run recorded (ops that exceeded
/// `--slow-op-us`, newest beyond the ring capacity dropped).
fn print_slow_ops(reg: &MetricsRegistry, slow_op_us: u64) {
    let slow = reg.slow_ops();
    println!(
        "  slow ops (>= {slow_op_us} us): {} recorded, {} dropped at capacity",
        slow.len(),
        reg.slow_ops_dropped()
    );
    for op in slow.iter().take(8) {
        println!(
            "    {:>12} us  {:11}  {}",
            op.duration.as_nanos() / 1_000,
            op.class.label(),
            op.backend
        );
    }
    if slow.len() > 8 {
        println!("    ... and {} more", slow.len() - 8);
    }
}

/// `fdbctl hammer --system daos --testbed gcp --servers 4 --clients 8
/// [--io-depth n|auto] [--index-cache]
/// [--coalesce-gap sz] [--coalesce-max sz]
/// [--wrapper tiered|replicated[:n]|sharded[:n]]
/// [--read-policy first|rr|fastest] [--slow-op-us n] [--metrics path]
/// [--durable] [--fault spec] ...`
pub fn cmd_hammer(args: &Args) -> Result<()> {
    let metrics_path = args
        .value_of("metrics")
        .map_err(|e| anyhow::anyhow!(e))?
        .map(str::to_string);
    let slow_op_us = num(args, "slow-op-us", 0u64)?;
    // the registry is only attached when asked for: metrics off is the
    // zero-overhead default
    let reg = (metrics_path.is_some() || slow_op_us > 0).then(MetricsRegistry::new);
    let (dep, cfg) = hammer_workload(args, reg.as_ref())?;
    let (testbed, kind) = (dep.testbed, dep.kind);
    let (servers, clients) = (num(args, "servers", 4usize)?, num(args, "clients", 8usize)?);
    let (r, trace) = hammer::run(&dep, cfg);
    println!(
        "fdb-hammer {} [{}] on {} ({} srv / {} cli × {} procs, {} fields/proc of {}, io-depth {}{})",
        kind.label(),
        dep.backend_config().describe(),
        testbed.name(),
        servers,
        clients,
        cfg.procs_per_node,
        cfg.fields_per_proc(),
        crate::util::humansize::fmt_bytes(cfg.field_size),
        dep.io.depth,
        match (dep.io.coalesce_enabled(), dep.io.durable) {
            (true, durable) => format!(
                ", coalesce gap {} / max {}{}",
                crate::util::humansize::fmt_bytes(dep.io.coalesce_gap),
                crate::util::humansize::fmt_bytes(dep.io.coalesce_max),
                if durable { ", durable" } else { "" }
            ),
            (false, true) => ", durable".to_string(),
            (false, false) => String::new(),
        },
    );
    println!("  write: {:8.2} GiB/s   ({})", r.gibs_w(), r.write_time);
    println!("  read:  {:8.2} GiB/s   ({})", r.gibs_r(), r.read_time);
    println!("  profile: {}", trace.render());
    if cfg.check {
        if cfg.faults_ok {
            println!("  consistency check: PASSED (retrieved fields byte-verified under faults)");
        } else {
            println!("  consistency check: PASSED (all fields found, bytes verified)");
        }
    }
    if let Some(reg) = &reg {
        if slow_op_us > 0 {
            print_slow_ops(reg, slow_op_us);
        }
        if let Some(path) = &metrics_path {
            write_metrics_json(reg, path)?;
        }
    }
    Ok(())
}

/// `fdbctl trace --out trace.json [hammer options]`: run the fdb-hammer
/// workload with the op-level event journal on and export it as Chrome
/// trace-event JSON (load in `chrome://tracing` / Perfetto).
pub fn cmd_trace(args: &Args) -> Result<()> {
    let out = opt(args, "out", "trace.json")?.to_string();
    let reg = MetricsRegistry::new();
    if let Some(cap) = args.value_of("journal-cap").map_err(|e| anyhow::anyhow!(e))? {
        let cap: usize = cap
            .parse()
            .map_err(|_| anyhow::anyhow!("--journal-cap must be a number (got `{cap}`)"))?;
        reg.set_journal_capacity(cap);
    }
    let (dep, cfg) = hammer_workload(args, Some(&reg))?;
    let _ = hammer::run(&dep, cfg);
    std::fs::write(&out, format!("{}", reg.chrome_trace()))
        .map_err(|e| anyhow::anyhow!("write {out}: {e}"))?;
    println!(
        "wrote {} trace events to {out} ({} dropped at ring capacity)",
        reg.journal_len(),
        reg.journal_dropped()
    );
    Ok(())
}

/// `fdbctl metrics [--out file] [hammer options]`: run the fdb-hammer
/// workload with the registry on and print (or write) the
/// Prometheus-style text exposition of every counter, gauge, and
/// histogram it collected.
pub fn cmd_metrics(args: &Args) -> Result<()> {
    let reg = MetricsRegistry::new();
    let (dep, cfg) = hammer_workload(args, Some(&reg))?;
    let _ = hammer::run(&dep, cfg);
    let text = reg.render_prometheus();
    match args.value_of("out").map_err(|e| anyhow::anyhow!(e))? {
        Some(path) => {
            std::fs::write(path, &text).map_err(|e| anyhow::anyhow!("write {path}: {e}"))?;
            println!("wrote {path}");
        }
        None => print!("{text}"),
    }
    Ok(())
}

/// `fdbctl crash --seed 42 --kill 9 --nfields 24 [--wrapper replicated:2]
/// [--field-size sz]`: one seeded crash-recovery run on the WAL'd POSIX
/// catalogue — a durable writer is fail-stopped after `--kill` store
/// writes, a fresh instance replays the WAL, and every recovered field
/// is byte-verified (the CI durability smoke).
pub fn cmd_crash(args: &Args) -> Result<()> {
    let kind = parse_system(opt(args, "system", "lustre")?)?;
    if kind != SystemKind::Lustre {
        bail!("crash recovery exercises the WAL'd POSIX catalogue (--system lustre)");
    }
    let wrapper_spec = opt(args, "wrapper", "none")?;
    let wrapper = parse_wrapper(wrapper_spec)?;
    let seed = num(args, "seed", 42u64)?;
    let nfields = num(args, "nfields", 24usize)?;
    let kill = num(args, "kill", (nfields / 2) as u64)?;
    let field_size = size(args, "field-size", 64 << 10)?;
    let metrics_path = args
        .value_of("metrics")
        .map_err(|e| anyhow::anyhow!(e))?
        .map(str::to_string);
    let reg = metrics_path.as_ref().map(|_| MetricsRegistry::new());
    let r = crate::bench::crash::crash_archive_observed(
        kind,
        wrapper,
        seed,
        kill,
        nfields,
        field_size,
        crate::fdb::IoProfile::default().with_durable(true),
        parse_resilience(args)?,
        reg.as_ref(),
    );
    println!(
        "crash-recovery {} [{}] seed {seed} kill@{kill}: archived {}/{} fields before the fault",
        kind.label(),
        wrapper_spec,
        r.archived,
        r.attempted,
    );
    println!(
        "  WAL replay: {} intents replayed, {} committed, {} data-missing, {} torn bytes",
        r.stats.replayed, r.stats.committed, r.stats.data_missing, r.stats.torn_bytes
    );
    println!("  recovery time: {:.3} ms (virtual)", r.recovery_ms);
    println!("  verified: {} byte-identical, ghosts: {}", r.verified, r.ghosts);
    if r.verified != r.archived || r.ghosts != 0 {
        bail!(
            "recovery check FAILED: {}/{} fields verified, {} ghost entries",
            r.verified,
            r.archived,
            r.ghosts
        );
    }
    println!("  recovery check: PASSED (index and data agree at the kill point)");
    if let (Some(reg), Some(path)) = (&reg, &metrics_path) {
        write_metrics_json(reg, path)?;
    }
    Ok(())
}

/// `fdbctl degrade --seed n [--copies n] [--kill n] [--nfields n]
/// [--field-size sz] [--retry n] [--op-deadline-us n] [--hedge-us n]
/// [--quarantine-after n] [--metrics out.json]`: the replica-loss
/// scenario — a replicated reader loses one replica after `--kill`
/// reads, mid-retrieve-storm. Exits non-zero if any read surfaces a
/// caller-visible error or comes back corrupt; reports degraded vs
/// healthy read p99 and the resilience counters that absorbed the
/// loss. Unlike the other commands, the resilience layer defaults ON
/// here (retries + hedging + quarantine) — flags override each knob.
pub fn cmd_degrade(args: &Args) -> Result<()> {
    let kind = parse_system(opt(args, "system", "lustre")?)?;
    if kind == SystemKind::Null {
        bail!("degrade needs a deployed storage system (lustre|daos|ceph)");
    }
    let copies = num(args, "copies", 3usize)?;
    if copies < 2 {
        bail!("degrade needs a replicated deployment (--copies >= 2)");
    }
    let seed = num(args, "seed", 42u64)?;
    let nfields = num(args, "nfields", 24usize)?;
    let kill = num(args, "kill", (nfields / 4) as u64)?;
    let field_size = size(args, "field-size", 64 << 10)?;
    let res = ResilienceProfile::retries(num(args, "retry", 3u32)?)
        .with_seed(seed)
        .with_backoff_us(num(args, "retry-backoff-us", 200u64)?)
        .with_op_deadline_us(num(args, "op-deadline-us", 0u64)?)
        .with_hedge_us(num(args, "hedge-us", 500u64)?)
        .with_quarantine(
            num(args, "quarantine-after", 2u32)?,
            num(args, "quarantine-backoff-us", 5_000u64)?,
        );
    res.validate()
        .map_err(|e| anyhow::anyhow!("--retry/--hedge-us/--quarantine-after: {e}"))?;
    let metrics_path = args
        .value_of("metrics")
        .map_err(|e| anyhow::anyhow!(e))?
        .map(str::to_string);
    let reg = metrics_path.as_ref().map(|_| MetricsRegistry::new());
    let r = crate::bench::degrade::degraded_read(
        kind,
        copies,
        seed,
        kill,
        nfields,
        field_size,
        crate::fdb::IoProfile::default(),
        res,
        reg.as_ref(),
    );
    println!(
        "degrade {} replicated:{copies} seed {seed} kill@{kill}: {} fields × {} retrieve rounds",
        kind.label(),
        r.fields,
        r.rounds,
    );
    println!(
        "  read p99: healthy {:.1} us, degraded {:.1} us ({:.2}x)",
        r.healthy_p99_us,
        r.degraded_p99_us,
        if r.healthy_p99_us > 0.0 {
            r.degraded_p99_us / r.healthy_p99_us
        } else {
            0.0
        },
    );
    println!(
        "  resilience: {} hedges launched, {} retries, {} quarantine ejections",
        r.hedges, r.retries, r.quarantined
    );
    if r.read_errors > 0 || r.verify_failures > 0 {
        bail!(
            "degraded reads FAILED: {} caller-visible errors, {} corrupt/missing fields{}",
            r.read_errors,
            r.verify_failures,
            r.first_error
                .as_deref()
                .map(|e| format!(" (first: {e})"))
                .unwrap_or_default(),
        );
    }
    println!(
        "  degraded-read check: PASSED ({} reads byte-verified under replica loss)",
        r.reads_ok
    );
    if let (Some(reg), Some(path)) = (&reg, &metrics_path) {
        write_metrics_json(reg, path)?;
    }
    Ok(())
}

/// `fdbctl fsck`: the online scrub/repair smoke — archive a dataset
/// with seeded damage (bit rot via `corrupt:*` fault rules, ghost
/// entries, orphaned containers), run the catalogue↔store cross-check,
/// optionally `--repair` plus a detect-only convergence pass, then
/// byte-verify every surviving field through a fresh reader.
///
/// Exit codes: 0 = clean (or the repair converged, the second pass is
/// clean, and readers saw zero corruption); 1 = unrepaired damage;
/// 2 = usage.
pub fn cmd_fsck(args: &Args) -> Result<()> {
    use crate::bench::scrub::{scrub_storm, ScrubConfig, GROUP};

    fn usage_err(msg: &str) -> ! {
        eprintln!("fsck: {msg}");
        std::process::exit(2);
    }
    let kind = parse_system(opt(args, "system", "lustre")?)?;
    let copies = num(args, "copies", 2usize)?;
    let ghosts = args.flag("ghosts");
    let orphans = args.flag("orphans");
    let repair = args.flag("repair");
    let write_rot = num(args, "write-rot", 0.0f64)?;
    let read_rot = num(args, "read-rot", 0.0f64)?;
    if kind == SystemKind::Null {
        usage_err("needs a byte-addressed backend (lustre|daos|ceph)");
    }
    if copies == 0 {
        usage_err("--copies must be >= 1");
    }
    if (ghosts || orphans) && copies != 1 {
        usage_err("--ghosts/--orphans seed container-granular damage: use --copies 1");
    }
    if !(0.0..=1.0).contains(&write_rot) || !(0.0..=1.0).contains(&read_rot) {
        usage_err("--write-rot/--read-rot must be probabilities in [0, 1]");
    }
    let cfg = ScrubConfig {
        kind,
        copies,
        seed: num(args, "seed", 42u64)?,
        nfields: num(args, "nfields", 3 * GROUP)?.max(3 * GROUP),
        field_size: size(args, "field-size", 64 << 10)?,
        write_rot,
        read_rot,
        ghosts,
        orphans,
        repair,
    };
    let metrics_path = args
        .value_of("metrics")
        .map_err(|e| anyhow::anyhow!(e))?
        .map(str::to_string);
    let reg = metrics_path.as_ref().map(|_| MetricsRegistry::new());
    let r = scrub_storm(&cfg, reg.as_ref());
    println!(
        "fsck {} copies={copies} seed {} ({} fields; rot write={write_rot} read={read_rot}; \
         ghosts={ghosts} orphans={orphans})",
        kind.label(),
        cfg.seed,
        r.fields,
    );
    println!(
        "  pass 1{}: {}",
        if repair { " (repair)" } else { "" },
        r.first
    );
    if let Some(second) = &r.second {
        println!("  pass 2 (verify): {second}");
    }
    println!(
        "  reader: {} verified, {} errors, {} corrupt/missing{}",
        r.reads_ok,
        r.read_errors,
        r.verify_failures,
        r.first_error
            .as_deref()
            .map(|e| format!(" (first: {e})"))
            .unwrap_or_default()
    );
    if let (Some(reg), Some(path)) = (&reg, &metrics_path) {
        write_metrics_json(reg, path)?;
    }
    let healthy = if repair {
        r.passed(true)
    } else {
        r.first.clean() && r.read_errors == 0 && r.verify_failures == 0
    };
    if !healthy {
        bail!(
            "fsck found unrepaired damage: {} ghosts, {} orphans, {} corrupt; \
             reader saw {} errors, {} corrupt/missing fields",
            r.first.ghosts,
            r.first.orphans,
            r.first.corrupt,
            r.read_errors,
            r.verify_failures
        );
    }
    println!(
        "  integrity check: PASSED{}",
        if repair {
            " (repair converged, second pass clean)"
        } else {
            " (dataset clean)"
        }
    );
    Ok(())
}

/// `fdbctl ior --system lustre ...`
pub fn cmd_ior(args: &Args) -> Result<()> {
    let testbed = parse_testbed(opt(args, "testbed", "gcp")?)?;
    let kind = parse_system(opt(args, "system", "lustre")?)?;
    if kind == SystemKind::Null {
        bail!("ior needs a deployed storage system (lustre|daos|ceph)");
    }
    let dep = deploy(
        testbed,
        kind,
        num(args, "servers", 4usize)?,
        num(args, "clients", 8usize)?,
        RedundancyOpt::None,
    );
    let cfg = ior::IorConfig {
        procs_per_node: num(args, "procs", 8usize)?,
        nops: num(args, "nops", 100usize)?,
        xfer: size(args, "xfer", 1 << 20)?,
        daos_via_dfs: args.flag("dfs"),
    };
    let r = ior::run(&dep, cfg);
    println!(
        "IOR {} on {}: write {:.2} GiB/s, read {:.2} GiB/s",
        kind.label(),
        testbed.name(),
        r.gibs_w(),
        r.gibs_r()
    );
    Ok(())
}

/// `fdbctl fieldio --system daos [--dummy] ...`
pub fn cmd_fieldio(args: &Args) -> Result<()> {
    let testbed = parse_testbed(opt(args, "testbed", "nextgenio")?)?;
    let kind = parse_system(opt(args, "system", "daos")?)?;
    if !matches!(kind, SystemKind::Daos | SystemKind::Lustre) {
        bail!("fieldio was a DAOS/Lustre PoC (thesis App. B)");
    }
    let dep = deploy(
        testbed,
        kind,
        num(args, "servers", 2usize)?,
        num(args, "clients", 4usize)?,
        RedundancyOpt::None,
    );
    let cfg = fieldio::FieldIoConfig {
        procs_per_node: num(args, "procs", 8usize)?,
        nfields: num(args, "nfields", 200usize)?,
        field_size: size(args, "field-size", 1 << 20)?,
        dummy: args.flag("dummy"),
        contention: args.flag("contention"),
        ..Default::default()
    };
    let r = fieldio::run(&dep, cfg);
    println!(
        "Field I/O {}{} on {}: write {:.2} GiB/s, read {:.2} GiB/s",
        kind.label(),
        if cfg.dummy { " (dummy)" } else { "" },
        testbed.name(),
        r.gibs_w(),
        r.gibs_r()
    );
    Ok(())
}

/// `fdbctl figures [--only figN_M] [--scale 0.05] [--json out.json]`
/// With `--json`, the figures that ran are also written as a JSON array
/// (machine-readable benchmark record, e.g. `BENCH_iodepth.json` from
/// `--only abl_iodepth` in CI).
pub fn cmd_figures(args: &Args) -> Result<()> {
    let scale = num(args, "scale", 0.05f64)?;
    let only = args.value_of("only").map_err(|e| anyhow::anyhow!(e))?;
    let json_path = args
        .value_of("json")
        .map_err(|e| anyhow::anyhow!(e))?
        .map(str::to_string);
    let mut ids = crate::bench::figures::all_ids();
    ids.extend(crate::bench::ablations::ablation_ids());
    let mut emitted = Vec::new();
    for id in ids {
        if let Some(filter) = only {
            if filter != id {
                continue;
            }
        }
        let t0 = std::time::Instant::now();
        let fig = crate::bench::figures::run_figure(id, scale)
            .or_else(|| crate::bench::ablations::run_ablation(id, scale));
        match fig {
            Some(fig) => {
                print!("{}", fig.render());
                println!("   [{:.1}s wall]", t0.elapsed().as_secs_f64());
                emitted.push(fig.to_json());
            }
            None => bail!("unknown figure id `{id}`"),
        }
    }
    if let Some(path) = json_path {
        let doc = crate::util::json::Json::Arr(emitted);
        std::fs::write(&path, format!("{doc}"))
            .map_err(|e| anyhow::anyhow!("write {path}: {e}"))?;
        println!("wrote {path}");
    }
    Ok(())
}

/// `fdbctl opsrun --system daos --members 2 --steps 4 [--no-compute]`
/// The end-to-end driver: operational workflow with real PGEN compute
/// through the PJRT artifacts.
pub fn cmd_opsrun(args: &Args) -> Result<()> {
    let testbed = parse_testbed(opt(args, "testbed", "gcp")?)?;
    let kind = parse_system(opt(args, "system", "daos")?)?;
    // the I/O profile reaches the I/O servers through the deployment:
    // every `dep.fdb_traced` instance (writers and PGEN readers) gets
    // the queue depth AND the read-plan coalescing knobs
    let io = crate::fdb::IoProfile::depth(parse_io_depth(args, kind)?)
        .with_coalesce_gap(size(args, "coalesce-gap", 0)?)
        .with_coalesce_max(size(
            args,
            "coalesce-max",
            crate::fdb::IoProfile::DEFAULT_COALESCE_MAX,
        )?);
    io.validate()
        .map_err(|e| anyhow::anyhow!("--io-depth/--coalesce-*: {e}"))?;
    let metrics_path = args
        .value_of("metrics")
        .map_err(|e| anyhow::anyhow!(e))?
        .map(str::to_string);
    let reg = metrics_path.as_ref().map(|_| MetricsRegistry::new());
    let mut dep = deploy(
        testbed,
        kind,
        num(args, "servers", 2usize)?,
        num(args, "clients", 4usize)?,
        RedundancyOpt::None,
    )
    .with_io(io);
    if let Some(res) = parse_resilience(args)? {
        dep = dep.with_resilience(res);
    }
    if let Some(reg) = &reg {
        dep = dep.with_metrics(reg);
    }
    let grid = num(args, "grid", 64usize)?;
    let real_compute = !args.flag("no-compute");
    let compute: Compute = if real_compute {
        let rt = PjrtRuntime::cpu()?;
        println!("PJRT platform: {}", rt.platform());
        Rc::new(PgenPipeline::new(&rt, 8, grid)?)
    } else {
        Rc::new(NullCompute)
    };
    let cfg = OperationalConfig {
        members: num(args, "members", 2usize)?,
        procs_per_member: num(args, "procs-per-member", 4usize)?,
        steps: num(args, "steps", 4u32)?,
        fields_per_proc_step: num(args, "fields-per-step", 8u32)?,
        grid,
        real_compute,
    };
    let report = driver::run(&dep, cfg, compute);
    println!(
        "operational run on {} / {}: {} members × {} procs, {} steps",
        kind.label(),
        testbed.name(),
        cfg.members,
        cfg.procs_per_member,
        cfg.steps
    );
    println!(
        "  archived {} fields, post-processed {} fields ({}), {} products",
        report.fields_written,
        report.fields_read,
        crate::util::humansize::fmt_bytes(report.bytes),
        report.products
    );
    println!("  simulated makespan: {}", report.makespan);
    println!("  profile: {}", report.trace.render());
    assert_eq!(report.fields_read, report.fields_written);
    println!("  end-to-end check: PASSED (every archived field post-processed)");
    if let (Some(reg), Some(path)) = (&reg, &metrics_path) {
        write_metrics_json(reg, path)?;
    }
    Ok(())
}

/// `fdbctl admin --system daos`: demonstrate the management tools —
/// populate a demo dataset, print stats, wipe it, verify emptiness.
pub fn cmd_admin(args: &Args) -> Result<()> {
    let testbed = parse_testbed(opt(args, "testbed", "gcp")?)?;
    let kind = parse_system(opt(args, "system", "daos")?)?;
    if kind == SystemKind::Null {
        bail!("admin needs a wipe-capable backend (lustre|daos|ceph)");
    }
    let dep = deploy(testbed, kind, 2, 2, RedundancyOpt::None);
    let node = dep.client_nodes()[0].clone();
    // one declarative construction path for every backend
    let mut fdb = dep.fdb(&node);
    let nfields = num(args, "nfields", 32usize)?;
    dep.sim.spawn(async move {
        use crate::fdb::schema::example_identifier;
        for i in 0..nfields {
            let id = example_identifier().with("step", (i + 1).to_string());
            fdb.archive(&id, crate::util::content::Bytes::virt(1 << 20, i as u64))
                .await
                .unwrap();
        }
        fdb.flush().await.expect("flush");
        fdb.close().await.expect("close");
        let ds = example_identifier()
            .project(&fdb.schema.dataset.clone())
            .unwrap();
        let stats = fdb.stats(&ds).await;
        println!(
            "dataset {}: {} fields, {}, {} collocations",
            ds.canonical(),
            stats.fields,
            crate::util::humansize::fmt_bytes(stats.bytes),
            stats.collocations
        );
        let wiped = fdb.wipe(&ds).await;
        fdb.invalidate_preload(&ds);
        let after = fdb.stats(&ds).await;
        println!("wipe: {wiped}; fields after wipe: {}", after.fields);
        assert_eq!(after.fields, 0);
    });
    dep.sim.run();
    println!("admin tooling OK");
    Ok(())
}

pub fn usage() -> &'static str {
    "fdbctl — FDB-on-object-stores reproduction driver\n\
     \n\
     USAGE: fdbctl <command> [options]\n\
     \n\
     COMMANDS:\n\
       figures   regenerate the paper's tables/figures  [--only <id>] [--scale f]\n\
                 [--json out.json]\n\
       hammer    fdb-hammer                 [--system s] [--testbed t] [--servers n]\n\
                 [--clients n] [--procs n] [--steps n] [--params n] [--levels n]\n\
                 [--field-size sz] [--contention] [--check]\n\
                 [--io-depth n|auto] [--index-cache]\n\
                 [--coalesce-gap sz] [--coalesce-max sz]\n\
                 [--wrapper none|tiered|replicated[:n]|sharded[:n]]\n\
                 [--read-policy first|rr|fastest] [--metrics out.json]\n\
                 [--slow-op-us n]  (log + report ops slower than n us)\n\
                 [--durable] [--fault seed=n,failstop:<class>:<n>,torn:write:<n>,\n\
                  err:<class>:p<f>[:transient],slow:<class>:<us>,\n\
                  corrupt:<class>:p<f>[,only=<i>]]\n\
                  classes: write|read|flush|index|index-flush\n\
                  (corrupt: seeded bit rot, write|read classes only)\n\
                 [--retry n] [--retry-backoff-us n] [--op-deadline-us n]\n\
                 [--hedge-us n] [--quarantine-after n] [--quarantine-backoff-us n]\n\
       trace     run the hammer workload, export the op journal as Chrome\n\
                 trace-event JSON    [--out trace.json] [--journal-cap n]\n\
                 [+ all hammer options]\n\
       metrics   run the hammer workload, print the Prometheus-style text\n\
                 exposition of the registry   [--out file] [+ hammer options]\n\
       crash     seeded crash-recovery smoke on the WAL'd POSIX catalogue\n\
                 [--seed n] [--kill n] [--nfields n] [--field-size sz]\n\
                 [--wrapper none|replicated[:n]|sharded[:n]|tiered]\n\
                 [--metrics out.json] [+ resilience flags, see hammer]\n\
       degrade   replica-loss smoke: one reader replica fail-stopped after\n\
                 --kill reads, mid-retrieve-storm; exits non-zero if any\n\
                 degraded read fails or corrupts\n\
                 [--copies n] [--seed n] [--kill n] [--nfields n]\n\
                 [--field-size sz] [--metrics out.json]\n\
                 [+ resilience flags, see hammer — default ON here]\n\
       fsck      online scrub/repair smoke: seeded bit rot + ghost entries +\n\
                 orphaned objects, catalogue<->store cross-check, optional repair\n\
                 with a convergence pass; exits 0 clean/converged, 1 unrepaired,\n\
                 2 usage\n\
                 [--copies n] [--seed n] [--nfields n] [--field-size sz]\n\
                 [--write-rot p] [--read-rot p]  (seeded corrupt:write|read rot)\n\
                 [--ghosts] [--orphans]  (bare backend only: --copies 1)\n\
                 [--repair] [--metrics out.json]\n\
       ior       IOR-like generic benchmark [--system s] [--nops n] [--xfer sz] [--dfs]\n\
       fieldio   Field I/O PoC              [--system s] [--nfields n] [--dummy]\n\
       opsrun    end-to-end operational NWP run with PJRT PGEN compute\n\
                 [--system s] [--members n] [--steps n] [--grid 32|64] [--no-compute]\n\
                 [--io-depth n|auto] [--coalesce-gap sz] [--coalesce-max sz]\n\
                 [--metrics out.json] [+ resilience flags, see hammer]\n\
       admin     dataset stats + wipe demo   [--system s] [--nfields n]\n\
     \n\
     systems: lustre | daos | ceph | null      testbeds: nextgenio | gcp"
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parsers() {
        assert_eq!(parse_system("daos").unwrap(), SystemKind::Daos);
        assert_eq!(parse_system("posix").unwrap(), SystemKind::Lustre);
        assert_eq!(parse_system("null").unwrap(), SystemKind::Null);
        assert!(parse_system("zfs").is_err());
        assert_eq!(parse_testbed("gcp").unwrap(), Testbed::Gcp);
        assert!(parse_testbed("azure").is_err());
        assert_eq!(parse_wrapper("none").unwrap(), WrapperOpt::Bare);
        assert_eq!(parse_wrapper("tiered").unwrap(), WrapperOpt::Tiered);
        assert_eq!(
            parse_wrapper("replicated:3").unwrap(),
            WrapperOpt::Replicated(3)
        );
        assert_eq!(parse_wrapper("sharded").unwrap(), WrapperOpt::Sharded(4));
        assert!(parse_wrapper("raid0").is_err());
        assert!(parse_wrapper("replicated:x").is_err());
        assert!(parse_wrapper("replicated:0").is_err());
    }

    #[test]
    fn dangling_value_option_is_usage_error_not_panic() {
        // regression: `fdbctl hammer --system` (no value) used to fall
        // back silently to the default system; now it's a usage error
        let args = Args::parse(["--system".to_string()]);
        let err = cmd_hammer(&args).unwrap_err();
        assert!(err.to_string().contains("--system"), "{err}");
    }

    #[test]
    fn hammer_null_backend_smoke() {
        // the CI smoke configuration: zero-cost store, shared catalogue
        let args = Args::parse(
            "--system null --servers 1 --clients 2 --procs 2 --steps 2 --params 2 --levels 2 --field-size 65536"
                .split_whitespace()
                .map(String::from),
        );
        cmd_hammer(&args).unwrap();
    }

    #[test]
    fn hammer_wrapped_backend_smoke() {
        let args = Args::parse(
            "--system lustre --wrapper replicated:2 --servers 2 --clients 2 --procs 1 --steps 2 --params 2 --levels 1 --field-size 65536 --check"
                .split_whitespace()
                .map(String::from),
        );
        cmd_hammer(&args).unwrap();
    }

    #[test]
    fn hammer_coalesce_smoke() {
        // the CI coalesce smoke shape: planner + depth engine together
        let args = Args::parse(
            "--system lustre --coalesce-gap 65536 --io-depth 8 --index-cache --servers 2 --clients 2 --procs 1 --steps 2 --params 2 --levels 2 --field-size 65536 --check"
                .split_whitespace()
                .map(String::from),
        );
        cmd_hammer(&args).unwrap();
    }

    #[test]
    fn io_depth_auto_resolves_per_system() {
        let args = Args::parse(["--io-depth".to_string(), "auto".to_string()]);
        assert_eq!(parse_io_depth(&args, SystemKind::Lustre).unwrap(), 8);
        assert_eq!(parse_io_depth(&args, SystemKind::Daos).unwrap(), 16);
        assert_eq!(parse_io_depth(&args, SystemKind::Null).unwrap(), 4);
        let args = Args::parse(["--io-depth".to_string(), "6".to_string()]);
        assert_eq!(parse_io_depth(&args, SystemKind::Lustre).unwrap(), 6);
        let args = Args::parse(["--io-depth".to_string(), "many".to_string()]);
        assert!(parse_io_depth(&args, SystemKind::Lustre).is_err());
    }

    #[test]
    fn coalesce_gap_at_or_above_max_is_usage_error() {
        let args = Args::parse(
            "--system null --coalesce-gap 65536 --coalesce-max 4096"
                .split_whitespace()
                .map(String::from),
        );
        let err = cmd_hammer(&args).unwrap_err();
        assert!(err.to_string().contains("coalesce"), "{err}");
    }

    #[test]
    fn hammer_command_smoke() {
        let args = Args::parse(
            "--system daos --servers 2 --clients 2 --procs 2 --steps 2 --params 2 --levels 2 --field-size 65536"
                .split_whitespace()
                .map(String::from),
        );
        cmd_hammer(&args).unwrap();
    }

    #[test]
    fn hammer_fault_smoke() {
        // a seeded fault plan through the CLI: slow writes + a read
        // error rate; the run tolerates the injected typed errors
        let args = Args::parse(
            "--system lustre --durable --fault seed=5,slow:write:50,err:read:p0.1 --servers 2 --clients 2 --procs 1 --steps 2 --params 2 --levels 1 --field-size 65536 --check"
                .split_whitespace()
                .map(String::from),
        );
        cmd_hammer(&args).unwrap();
    }

    #[test]
    fn hammer_bad_fault_spec_is_usage_error() {
        let args = Args::parse(
            "--system null --fault bogus:write:1"
                .split_whitespace()
                .map(String::from),
        );
        let err = cmd_hammer(&args).unwrap_err();
        assert!(err.to_string().contains("--fault"), "{err}");
    }

    #[test]
    fn hammer_metrics_dump_and_slow_op_log_smoke() {
        // --metrics dumps the registry JSON; --slow-op-us 1 logs every
        // op (threshold 1us) and surfaces the slow-op summary
        let path = std::env::temp_dir().join("fdbr_test_hammer_metrics.json");
        let spec = format!(
            "--system lustre --servers 2 --clients 2 --procs 1 --steps 2 --params 2 --levels 1 --field-size 65536 --slow-op-us 1 --check --metrics {}",
            path.display()
        );
        let args = Args::parse(spec.split_whitespace().map(String::from));
        cmd_hammer(&args).unwrap();
        let text = std::fs::read_to_string(&path).unwrap();
        assert!(text.contains("engine.service.data-write"), "{text}");
        assert!(text.contains("slow_ops"), "{text}");
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn trace_command_writes_chrome_trace_events() {
        let path = std::env::temp_dir().join("fdbr_test_trace.json");
        let spec = format!(
            "--system null --servers 1 --clients 2 --procs 1 --steps 2 --params 2 --levels 1 --field-size 65536 --out {}",
            path.display()
        );
        let args = Args::parse(spec.split_whitespace().map(String::from));
        cmd_trace(&args).unwrap();
        let text = std::fs::read_to_string(&path).unwrap();
        // Chrome trace-event essentials: complete events with ts/dur
        assert!(text.contains("\"ph\""), "{text}");
        assert!(text.contains("\"dur\""), "{text}");
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn metrics_command_renders_prometheus_exposition() {
        let path = std::env::temp_dir().join("fdbr_test_metrics.prom");
        let spec = format!(
            "--system null --servers 1 --clients 2 --procs 1 --steps 2 --params 2 --levels 1 --field-size 65536 --out {}",
            path.display()
        );
        let args = Args::parse(spec.split_whitespace().map(String::from));
        cmd_metrics(&args).unwrap();
        let text = std::fs::read_to_string(&path).unwrap();
        assert!(text.contains("# TYPE"), "{text}");
        assert!(text.contains("fdb_engine_service_data_write"), "{text}");
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn read_policy_parser() {
        assert_eq!(parse_read_policy("first").unwrap(), ReadPolicy::FirstHealthy);
        assert_eq!(parse_read_policy("rr").unwrap(), ReadPolicy::RoundRobin);
        assert_eq!(parse_read_policy("fastest").unwrap(), ReadPolicy::Fastest);
        assert!(parse_read_policy("slowest").is_err());
    }

    #[test]
    fn hammer_resilience_flags_smoke() {
        // the resilience layer end-to-end through the CLI: a transient
        // read-error storm on a replicated store, masked by retries +
        // hedged reads + quarantine; --check byte-verifies every field
        let args = Args::parse(
            "--system lustre --wrapper replicated:2 --retry 3 --hedge-us 500 --quarantine-after 2 --fault seed=5,err:read:p0.2:transient --servers 2 --clients 2 --procs 1 --steps 2 --params 2 --levels 1 --field-size 65536 --check"
                .split_whitespace()
                .map(String::from),
        );
        cmd_hammer(&args).unwrap();
    }

    #[test]
    fn resilience_flag_bounds_are_usage_errors() {
        for bad in [
            "--system null --retry 0",
            "--system null --retry 99",
            "--system null --retry 3 --retry-backoff-us 0",
            "--system null --quarantine-after 2 --quarantine-backoff-us 0",
        ] {
            let args = Args::parse(bad.split_whitespace().map(String::from));
            assert!(cmd_hammer(&args).is_err(), "`{bad}` should be rejected");
        }
    }

    #[test]
    fn degrade_command_smoke() {
        // the CI replica-loss smoke shape: replicated reader loses one
        // replica mid-storm; the command exits cleanly only when every
        // degraded read byte-verifies
        let args = Args::parse(
            "--copies 2 --seed 7 --kill 3 --nfields 12 --field-size 4096"
                .split_whitespace()
                .map(String::from),
        );
        cmd_degrade(&args).unwrap();
    }

    #[test]
    fn degrade_rejects_unreplicated_deployments() {
        let args = Args::parse(["--copies".to_string(), "1".to_string()]);
        assert!(cmd_degrade(&args).is_err());
        let args = Args::parse(["--system".to_string(), "null".to_string()]);
        assert!(cmd_degrade(&args).is_err());
    }

    #[test]
    fn crash_command_smoke() {
        // the CI durability smoke shape: seeded kill, WAL replay, verify
        let args = Args::parse(
            "--seed 11 --kill 5 --nfields 12 --field-size 4096"
                .split_whitespace()
                .map(String::from),
        );
        cmd_crash(&args).unwrap();
        let args = Args::parse(
            "--wrapper replicated:2 --seed 11 --kill 5 --nfields 12 --field-size 4096"
                .split_whitespace()
                .map(String::from),
        );
        cmd_crash(&args).unwrap();
    }

    #[test]
    fn crash_rejects_non_posix_backends() {
        let args = Args::parse(["--system".to_string(), "daos".to_string()]);
        assert!(cmd_crash(&args).is_err());
    }

    #[test]
    fn opsrun_no_compute_smoke() {
        let args = Args::parse(
            "--system lustre --members 1 --steps 2 --grid 32 --no-compute"
                .split_whitespace()
                .map(String::from),
        );
        cmd_opsrun(&args).unwrap();
    }
}
