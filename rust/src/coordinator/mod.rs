//! The leader/coordinator: wires configuration → deployed simulated
//! cluster → workload → report, behind the `fdbctl` CLI and examples.

use std::rc::Rc;

use anyhow::{bail, Result};

use crate::bench::scenario::{deploy, RedundancyOpt, SystemKind};
use crate::bench::{fieldio, hammer, ior};
use crate::hw::profiles::Testbed;
use crate::runtime::{PgenPipeline, PjrtRuntime};
use crate::util::cli::Args;
use crate::workflow::driver::{self, OperationalConfig};
use crate::workflow::{Compute, NullCompute};

pub fn parse_testbed(s: &str) -> Result<Testbed> {
    Ok(match s {
        "nextgenio" | "ngio" => Testbed::NextGenIo,
        "gcp" => Testbed::Gcp,
        other => bail!("unknown testbed `{other}` (nextgenio|gcp)"),
    })
}

pub fn parse_system(s: &str) -> Result<SystemKind> {
    Ok(match s {
        "lustre" | "posix" => SystemKind::Lustre,
        "daos" => SystemKind::Daos,
        "ceph" | "rados" => SystemKind::Ceph,
        other => bail!("unknown system `{other}` (lustre|daos|ceph)"),
    })
}

/// `fdbctl hammer --system daos --testbed gcp --servers 4 --clients 8 ...`
pub fn cmd_hammer(args: &Args) -> Result<()> {
    let testbed = parse_testbed(args.get_or("testbed", "gcp"))?;
    let kind = parse_system(args.get_or("system", "daos"))?;
    let dep = deploy(
        testbed,
        kind,
        args.usize("servers", 4),
        args.usize("clients", 8),
        RedundancyOpt::None,
    );
    let cfg = hammer::HammerConfig {
        procs_per_node: args.usize("procs", 8),
        nsteps: args.u64("steps", 10) as u32,
        nparams: args.u64("params", 5) as u32,
        nlevels: args.u64("levels", 4) as u32,
        field_size: args.bytes("field-size", 1 << 20),
        check: args.flag("check"),
        contention: args.flag("contention"),
    };
    let (r, trace) = hammer::run(&dep, cfg);
    println!(
        "fdb-hammer {} on {} ({} srv / {} cli × {} procs, {} fields/proc of {})",
        kind.label(),
        testbed.name(),
        args.usize("servers", 4),
        args.usize("clients", 8),
        cfg.procs_per_node,
        cfg.fields_per_proc(),
        crate::util::humansize::fmt_bytes(cfg.field_size),
    );
    println!("  write: {:8.2} GiB/s   ({})", r.gibs_w(), r.write_time);
    println!("  read:  {:8.2} GiB/s   ({})", r.gibs_r(), r.read_time);
    println!("  profile: {}", trace.render());
    if cfg.check {
        println!("  consistency check: PASSED (all fields found, bytes verified)");
    }
    Ok(())
}

/// `fdbctl ior --system lustre ...`
pub fn cmd_ior(args: &Args) -> Result<()> {
    let testbed = parse_testbed(args.get_or("testbed", "gcp"))?;
    let kind = parse_system(args.get_or("system", "lustre"))?;
    let dep = deploy(
        testbed,
        kind,
        args.usize("servers", 4),
        args.usize("clients", 8),
        RedundancyOpt::None,
    );
    let cfg = ior::IorConfig {
        procs_per_node: args.usize("procs", 8),
        nops: args.usize("nops", 100),
        xfer: args.bytes("xfer", 1 << 20),
        daos_via_dfs: args.flag("dfs"),
    };
    let r = ior::run(&dep, cfg);
    println!(
        "IOR {} on {}: write {:.2} GiB/s, read {:.2} GiB/s",
        kind.label(),
        testbed.name(),
        r.gibs_w(),
        r.gibs_r()
    );
    Ok(())
}

/// `fdbctl fieldio --system daos [--dummy] ...`
pub fn cmd_fieldio(args: &Args) -> Result<()> {
    let testbed = parse_testbed(args.get_or("testbed", "nextgenio"))?;
    let kind = parse_system(args.get_or("system", "daos"))?;
    let dep = deploy(
        testbed,
        kind,
        args.usize("servers", 2),
        args.usize("clients", 4),
        RedundancyOpt::None,
    );
    let cfg = fieldio::FieldIoConfig {
        procs_per_node: args.usize("procs", 8),
        nfields: args.usize("nfields", 200),
        field_size: args.bytes("field-size", 1 << 20),
        dummy: args.flag("dummy"),
        contention: args.flag("contention"),
        ..Default::default()
    };
    let r = fieldio::run(&dep, cfg);
    println!(
        "Field I/O {}{} on {}: write {:.2} GiB/s, read {:.2} GiB/s",
        kind.label(),
        if cfg.dummy { " (dummy)" } else { "" },
        testbed.name(),
        r.gibs_w(),
        r.gibs_r()
    );
    Ok(())
}

/// `fdbctl figures [--only figN_M] [--scale 0.05]`
pub fn cmd_figures(args: &Args) -> Result<()> {
    let scale = args.f64("scale", 0.05);
    let only = args.get("only");
    let mut ids = crate::bench::figures::all_ids();
    ids.extend(crate::bench::ablations::ablation_ids());
    for id in ids {
        if let Some(filter) = only {
            if filter != id {
                continue;
            }
        }
        let t0 = std::time::Instant::now();
        let fig = crate::bench::figures::run_figure(id, scale)
            .or_else(|| crate::bench::ablations::run_ablation(id, scale));
        match fig {
            Some(fig) => {
                print!("{}", fig.render());
                println!("   [{:.1}s wall]", t0.elapsed().as_secs_f64());
            }
            None => bail!("unknown figure id `{id}`"),
        }
    }
    Ok(())
}

/// `fdbctl opsrun --system daos --members 2 --steps 4 [--no-compute]`
/// The end-to-end driver: operational workflow with real PGEN compute
/// through the PJRT artifacts.
pub fn cmd_opsrun(args: &Args) -> Result<()> {
    let testbed = parse_testbed(args.get_or("testbed", "gcp"))?;
    let kind = parse_system(args.get_or("system", "daos"))?;
    let dep = deploy(
        testbed,
        kind,
        args.usize("servers", 2),
        args.usize("clients", 4),
        RedundancyOpt::None,
    );
    let grid = args.usize("grid", 64);
    let real_compute = !args.flag("no-compute");
    let compute: Compute = if real_compute {
        let rt = PjrtRuntime::cpu()?;
        println!("PJRT platform: {}", rt.platform());
        Rc::new(PgenPipeline::new(&rt, 8, grid)?)
    } else {
        Rc::new(NullCompute)
    };
    let cfg = OperationalConfig {
        members: args.usize("members", 2),
        procs_per_member: args.usize("procs-per-member", 4),
        steps: args.u64("steps", 4) as u32,
        fields_per_proc_step: args.u64("fields-per-step", 8) as u32,
        grid,
        real_compute,
    };
    let report = driver::run(&dep, cfg, compute);
    println!(
        "operational run on {} / {}: {} members × {} procs, {} steps",
        kind.label(),
        testbed.name(),
        cfg.members,
        cfg.procs_per_member,
        cfg.steps
    );
    println!(
        "  archived {} fields, post-processed {} fields ({}), {} products",
        report.fields_written,
        report.fields_read,
        crate::util::humansize::fmt_bytes(report.bytes),
        report.products
    );
    println!("  simulated makespan: {}", report.makespan);
    println!("  profile: {}", report.trace.render());
    assert_eq!(report.fields_read, report.fields_written);
    println!("  end-to-end check: PASSED (every archived field post-processed)");
    Ok(())
}

/// `fdbctl admin --system daos`: demonstrate the management tools —
/// populate a demo dataset, print stats, wipe it, verify emptiness.
pub fn cmd_admin(args: &Args) -> Result<()> {
    let testbed = parse_testbed(args.get_or("testbed", "gcp"))?;
    let kind = parse_system(args.get_or("system", "daos"))?;
    let dep = deploy(testbed, kind, 2, 2, RedundancyOpt::None);
    let node = dep.client_nodes()[0].clone();
    // one declarative construction path for every backend
    let mut fdb = dep.fdb(&node);
    let nfields = args.usize("nfields", 32);
    dep.sim.spawn(async move {
        use crate::fdb::schema::example_identifier;
        for i in 0..nfields {
            let id = example_identifier().with("step", (i + 1).to_string());
            fdb.archive(&id, crate::util::content::Bytes::virt(1 << 20, i as u64))
                .await
                .unwrap();
        }
        fdb.flush().await;
        fdb.close().await;
        let ds = example_identifier()
            .project(&fdb.schema.dataset.clone())
            .unwrap();
        let stats = fdb.stats(&ds).await;
        println!(
            "dataset {}: {} fields, {}, {} collocations",
            ds.canonical(),
            stats.fields,
            crate::util::humansize::fmt_bytes(stats.bytes),
            stats.collocations
        );
        let wiped = fdb.wipe(&ds).await;
        fdb.invalidate_preload(&ds);
        let after = fdb.stats(&ds).await;
        println!("wipe: {wiped}; fields after wipe: {}", after.fields);
        assert_eq!(after.fields, 0);
    });
    dep.sim.run();
    println!("admin tooling OK");
    Ok(())
}

pub fn usage() -> &'static str {
    "fdbctl — FDB-on-object-stores reproduction driver\n\
     \n\
     USAGE: fdbctl <command> [options]\n\
     \n\
     COMMANDS:\n\
       figures   regenerate the paper's tables/figures  [--only <id>] [--scale f]\n\
       hammer    fdb-hammer                 [--system s] [--testbed t] [--servers n]\n\
                 [--clients n] [--procs n] [--steps n] [--params n] [--levels n]\n\
                 [--field-size sz] [--contention] [--check]\n\
       ior       IOR-like generic benchmark [--system s] [--nops n] [--xfer sz] [--dfs]\n\
       fieldio   Field I/O PoC              [--system s] [--nfields n] [--dummy]\n\
       opsrun    end-to-end operational NWP run with PJRT PGEN compute\n\
                 [--system s] [--members n] [--steps n] [--grid 32|64] [--no-compute]\n\
       admin     dataset stats + wipe demo   [--system s] [--nfields n]\n\
     \n\
     systems: lustre | daos | ceph      testbeds: nextgenio | gcp"
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parsers() {
        assert_eq!(parse_system("daos").unwrap(), SystemKind::Daos);
        assert_eq!(parse_system("posix").unwrap(), SystemKind::Lustre);
        assert!(parse_system("zfs").is_err());
        assert_eq!(parse_testbed("gcp").unwrap(), Testbed::Gcp);
        assert!(parse_testbed("azure").is_err());
    }

    #[test]
    fn hammer_command_smoke() {
        let args = Args::parse(
            "--system daos --servers 2 --clients 2 --procs 2 --steps 2 --params 2 --levels 2 --field-size 65536"
                .split_whitespace()
                .map(String::from),
        );
        cmd_hammer(&args).unwrap();
    }

    #[test]
    fn opsrun_no_compute_smoke() {
        let args = Args::parse(
            "--system lustre --members 1 --steps 2 --grid 32 --no-compute"
                .split_whitespace()
                .map(String::from),
        );
        cmd_opsrun(&args).unwrap();
    }
}
