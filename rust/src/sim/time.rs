//! Virtual time for the discrete-event simulation: nanosecond ticks.

use std::fmt;
use std::ops::{Add, AddAssign, Sub};

/// A point in (or span of) virtual time, in nanoseconds.
#[derive(Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Hash, Default)]
pub struct SimTime(pub u64);

impl SimTime {
    pub const ZERO: SimTime = SimTime(0);

    pub fn nanos(n: u64) -> SimTime {
        SimTime(n)
    }
    pub fn micros(us: u64) -> SimTime {
        SimTime(us * 1_000)
    }
    pub fn millis(ms: u64) -> SimTime {
        SimTime(ms * 1_000_000)
    }
    pub fn secs(s: u64) -> SimTime {
        SimTime(s * 1_000_000_000)
    }

    /// From fractional seconds, rounding to the nearest nanosecond.
    pub fn from_secs_f64(s: f64) -> SimTime {
        SimTime((s * 1e9).round().max(0.0) as u64)
    }

    pub fn as_nanos(self) -> u64 {
        self.0
    }
    pub fn as_secs_f64(self) -> f64 {
        self.0 as f64 / 1e9
    }

    pub fn saturating_sub(self, rhs: SimTime) -> SimTime {
        SimTime(self.0.saturating_sub(rhs.0))
    }

    pub fn max(self, rhs: SimTime) -> SimTime {
        SimTime(self.0.max(rhs.0))
    }
    pub fn min(self, rhs: SimTime) -> SimTime {
        SimTime(self.0.min(rhs.0))
    }
}

impl Add for SimTime {
    type Output = SimTime;
    fn add(self, rhs: SimTime) -> SimTime {
        SimTime(self.0 + rhs.0)
    }
}

impl AddAssign for SimTime {
    fn add_assign(&mut self, rhs: SimTime) {
        self.0 += rhs.0;
    }
}

impl Sub for SimTime {
    type Output = SimTime;
    fn sub(self, rhs: SimTime) -> SimTime {
        SimTime(self.0 - rhs.0)
    }
}

impl fmt::Debug for SimTime {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self)
    }
}

impl fmt::Display for SimTime {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        let ns = self.0;
        if ns >= 1_000_000_000 {
            write!(f, "{:.3}s", ns as f64 / 1e9)
        } else if ns >= 1_000_000 {
            write!(f, "{:.3}ms", ns as f64 / 1e6)
        } else if ns >= 1_000 {
            write!(f, "{:.3}us", ns as f64 / 1e3)
        } else {
            write!(f, "{ns}ns")
        }
    }
}

/// Duration for transferring `bytes` at `bytes_per_sec`.
pub fn transfer_time(bytes: u64, bytes_per_sec: f64) -> SimTime {
    if bytes_per_sec <= 0.0 {
        return SimTime::ZERO;
    }
    SimTime::from_secs_f64(bytes as f64 / bytes_per_sec)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn arithmetic() {
        let t = SimTime::micros(3) + SimTime::nanos(500);
        assert_eq!(t.as_nanos(), 3_500);
        assert_eq!((t - SimTime::nanos(500)).as_nanos(), 3_000);
    }

    #[test]
    fn conversions() {
        assert_eq!(SimTime::secs(2).as_secs_f64(), 2.0);
        assert_eq!(SimTime::from_secs_f64(1.5).as_nanos(), 1_500_000_000);
    }

    #[test]
    fn transfer() {
        // 1 GiB at 1 GiB/s = 1 s
        let t = transfer_time(1 << 30, (1u64 << 30) as f64);
        assert_eq!(t.as_nanos(), 1_000_000_000);
    }

    #[test]
    fn display_units() {
        assert_eq!(SimTime::nanos(5).to_string(), "5ns");
        assert_eq!(SimTime::micros(5).to_string(), "5.000us");
        assert_eq!(SimTime::secs(5).to_string(), "5.000s");
    }
}
