//! Per-operation-class virtual-time accounting — the "profiling" figures.
//!
//! The thesis presents profiling breakdowns (Figs 4.14/4.15/4.23–4.25)
//! showing where client processes spend time (data write, index ops,
//! metadata, locks, ...). Simulated processes report spans into a
//! [`Trace`] collector keyed by [`OpClass`]; the figure harness renders
//! the aggregate per-class percentages.

use std::cell::RefCell;
use std::collections::BTreeMap;
use std::rc::Rc;

use super::time::SimTime;

/// Operation classes matching the thesis' profiling categories.
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub enum OpClass {
    /// pool/container connect, mount, dataset-dir init
    Init,
    /// bulk object/field data writes
    DataWrite,
    /// bulk object/field data reads
    DataRead,
    /// index insert/put ops (KV put, B-tree insert, index file write)
    IndexWrite,
    /// index lookups (KV get/list, TOC/sub-TOC/index loads)
    IndexRead,
    /// metadata ops: file create/open/stat, OID alloc, namespace ops
    Meta,
    /// distributed-lock traffic (Lustre DLM only)
    Lock,
    /// flush/fsync barriers
    Flush,
    /// PGEN/model compute
    Compute,
    /// idle / waiting on barriers
    Wait,
}

impl OpClass {
    pub const ALL: [OpClass; 10] = [
        OpClass::Init,
        OpClass::DataWrite,
        OpClass::DataRead,
        OpClass::IndexWrite,
        OpClass::IndexRead,
        OpClass::Meta,
        OpClass::Lock,
        OpClass::Flush,
        OpClass::Compute,
        OpClass::Wait,
    ];

    pub fn label(self) -> &'static str {
        match self {
            OpClass::Init => "init",
            OpClass::DataWrite => "data-write",
            OpClass::DataRead => "data-read",
            OpClass::IndexWrite => "index-write",
            OpClass::IndexRead => "index-read",
            OpClass::Meta => "metadata",
            OpClass::Lock => "lock",
            OpClass::Flush => "flush",
            OpClass::Compute => "compute",
            OpClass::Wait => "wait",
        }
    }
}

#[derive(Default)]
struct TraceInner {
    spans: BTreeMap<OpClass, (SimTime, u64)>, // (total time, count)
    timeline: BTreeMap<OpClass, (SimTime, SimTime)>, // (earliest start, latest end)
}

/// Shared trace collector. Clone-cheap; one per benchmark run.
#[derive(Clone, Default)]
pub struct Trace {
    inner: Rc<RefCell<TraceInner>>,
}

impl Trace {
    pub fn new() -> Trace {
        Trace::default()
    }

    /// Record `dur` of virtual time under `class`.
    pub fn record(&self, class: OpClass, dur: SimTime) {
        let mut inner = self.inner.borrow_mut();
        let e = inner.spans.entry(class).or_insert((SimTime::ZERO, 0));
        e.0 += dur;
        e.1 += 1;
    }

    /// Observe the absolute window `[start, end]` of one span under
    /// `class`. Timeline-only: per-class totals/counts come from
    /// [`Trace::record`], which subtracts attributed sub-costs (lock
    /// time) — the timeline keeps the raw wall-clock endpoints so
    /// overlap between classes (did the first data read start before
    /// the last index lookup ended?) stays observable.
    pub fn observe_span(&self, class: OpClass, start: SimTime, end: SimTime) {
        let mut inner = self.inner.borrow_mut();
        let e = inner.timeline.entry(class).or_insert((start, end));
        e.0 = e.0.min(start);
        e.1 = e.1.max(end);
    }

    /// The observed `(earliest start, latest end)` window of `class`,
    /// or `None` if no span of that class was ever observed.
    pub fn span_window(&self, class: OpClass) -> Option<(SimTime, SimTime)> {
        self.inner.borrow().timeline.get(&class).copied()
    }

    pub fn total(&self, class: OpClass) -> SimTime {
        self.inner
            .borrow()
            .spans
            .get(&class)
            .map(|e| e.0)
            .unwrap_or(SimTime::ZERO)
    }

    pub fn count(&self, class: OpClass) -> u64 {
        self.inner
            .borrow()
            .spans
            .get(&class)
            .map(|e| e.1)
            .unwrap_or(0)
    }

    /// Sum over all classes.
    pub fn grand_total(&self) -> SimTime {
        SimTime(
            self.inner
                .borrow()
                .spans
                .values()
                .map(|e| e.0 .0)
                .sum::<u64>(),
        )
    }

    /// Percentage breakdown, ordered as [`OpClass::ALL`]. Classes that
    /// were never recorded are skipped; classes that WERE recorded stay
    /// listed even at zero duration (instant ops on a virtual-time-free
    /// backend), reported as `0.0%` — a zero grand total must never
    /// divide into NaN percentages.
    pub fn breakdown(&self) -> Vec<(OpClass, f64, SimTime)> {
        let total = self.grand_total().as_nanos() as f64;
        OpClass::ALL
            .iter()
            .filter_map(|&c| {
                let t = self.total(c);
                if t == SimTime::ZERO && self.count(c) == 0 {
                    None
                } else {
                    let pct = if total == 0.0 {
                        0.0
                    } else {
                        100.0 * t.as_nanos() as f64 / total
                    };
                    Some((c, pct, t))
                }
            })
            .collect()
    }

    /// Render a one-line textual bar-chart style breakdown.
    pub fn render(&self) -> String {
        self.breakdown()
            .iter()
            .map(|(c, pct, t)| format!("{}={:.1}% ({})", c.label(), pct, t))
            .collect::<Vec<_>>()
            .join("  ")
    }
}

/// RAII-less span helper: measure an async op's virtual duration.
#[macro_export]
macro_rules! traced {
    ($trace:expr, $sim:expr, $class:expr, $body:expr) => {{
        let __t0 = $sim.now();
        let __out = $body;
        $trace.record($class, $sim.now() - __t0);
        __out
    }};
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn records_and_breaks_down() {
        let t = Trace::new();
        t.record(OpClass::DataWrite, SimTime::micros(75));
        t.record(OpClass::IndexWrite, SimTime::micros(25));
        let b = t.breakdown();
        assert_eq!(b.len(), 2);
        assert!((b[0].1 - 75.0).abs() < 1e-9);
        assert!((b[1].1 - 25.0).abs() < 1e-9);
        assert_eq!(t.count(OpClass::DataWrite), 1);
    }

    #[test]
    fn empty_breakdown() {
        let t = Trace::new();
        assert!(t.breakdown().is_empty());
        assert_eq!(t.grand_total(), SimTime::ZERO);
        assert_eq!(t.render(), "");
    }

    #[test]
    fn zero_duration_spans_render_zero_percent_not_nan() {
        // instant ops (a Null backend costs no virtual time): the class
        // was recorded, the grand total is zero — the breakdown must
        // list it at exactly 0.0%, never NaN
        let t = Trace::new();
        t.record(OpClass::DataRead, SimTime::ZERO);
        t.record(OpClass::IndexRead, SimTime::ZERO);
        assert_eq!(t.grand_total(), SimTime::ZERO);
        let b = t.breakdown();
        assert_eq!(b.len(), 2);
        for (_, pct, _) in &b {
            assert_eq!(*pct, 0.0);
            assert!(!pct.is_nan());
        }
        let rendered = t.render();
        assert!(rendered.contains("data-read=0.0%"), "{rendered}");
        assert!(!rendered.contains("NaN"), "{rendered}");
    }

    #[test]
    fn zero_duration_class_listed_alongside_real_spans() {
        // a recorded-but-instant class stays visible next to real time
        let t = Trace::new();
        t.record(OpClass::DataRead, SimTime::ZERO);
        t.record(OpClass::DataWrite, SimTime::micros(10));
        let b = t.breakdown();
        assert_eq!(b.len(), 2);
        let read = b.iter().find(|(c, _, _)| *c == OpClass::DataRead).unwrap();
        assert_eq!(read.1, 0.0);
        let write = b.iter().find(|(c, _, _)| *c == OpClass::DataWrite).unwrap();
        assert!((write.1 - 100.0).abs() < 1e-9);
    }

    #[test]
    fn span_window_tracks_extremes_without_touching_totals() {
        let t = Trace::new();
        assert_eq!(t.span_window(OpClass::DataRead), None);
        t.observe_span(OpClass::DataRead, SimTime::micros(10), SimTime::micros(20));
        t.observe_span(OpClass::DataRead, SimTime::micros(5), SimTime::micros(12));
        t.observe_span(OpClass::DataRead, SimTime::micros(15), SimTime::micros(40));
        assert_eq!(
            t.span_window(OpClass::DataRead),
            Some((SimTime::micros(5), SimTime::micros(40)))
        );
        // timeline observation is not a `record`: totals stay empty
        assert_eq!(t.total(OpClass::DataRead), SimTime::ZERO);
        assert_eq!(t.count(OpClass::DataRead), 0);
    }

    #[test]
    fn render_contains_labels() {
        let t = Trace::new();
        t.record(OpClass::Lock, SimTime::micros(10));
        assert!(t.render().contains("lock"));
    }
}
