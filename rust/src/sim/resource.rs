//! Timed shared resources with FIFO queueing — the contention model.
//!
//! A [`Resource`] is a k-server queue: up to `servers` holders at once,
//! further acquirers wait in FIFO order. Service time is whatever the
//! holder awaits between acquire and release; the [`Resource::serve`]
//! helper wraps the common acquire → sleep(duration) → release pattern.
//!
//! Bandwidth-shaped resources (NICs, devices, wires) are modeled as
//! k-server queues whose service time is `latency + bytes/bandwidth`;
//! under load this yields the same aggregate throughput as fair sharing,
//! which is what the paper's figures measure.

use std::cell::{Cell, RefCell};
use std::collections::VecDeque;
use std::future::Future;
use std::pin::Pin;
use std::rc::Rc;
use std::task::{Context, Poll, Waker};

use super::exec::Sim;
use super::time::SimTime;

struct Waiter {
    granted: Rc<Cell<bool>>,
    waker: Waker,
}

/// FIFO k-server queue over virtual time.
pub struct Resource {
    name: String,
    free: Cell<usize>,
    servers: usize,
    waiters: RefCell<VecDeque<Waiter>>,
    /// cumulative busy time across servers (for utilization reports)
    busy: Cell<SimTime>,
    acquires: Cell<u64>,
}

impl Resource {
    pub fn new(name: impl Into<String>, servers: usize) -> Rc<Resource> {
        assert!(servers > 0);
        Rc::new(Resource {
            name: name.into(),
            free: Cell::new(servers),
            servers,
            waiters: RefCell::new(VecDeque::new()),
            busy: Cell::new(SimTime::ZERO),
            acquires: Cell::new(0),
        })
    }

    pub fn name(&self) -> &str {
        &self.name
    }

    pub fn servers(&self) -> usize {
        self.servers
    }

    pub fn acquires(&self) -> u64 {
        self.acquires.get()
    }

    /// Cumulative holder-occupancy time (only counted via `serve`).
    pub fn busy_time(&self) -> SimTime {
        self.busy.get()
    }

    /// Acquire one server slot; resolves in FIFO order.
    pub fn acquire(self: &Rc<Self>) -> Acquire {
        Acquire {
            res: self.clone(),
            granted: Rc::new(Cell::new(false)),
            queued: false,
        }
    }

    /// Release one server slot, handing it to the next FIFO waiter if any.
    pub fn release(self: &Rc<Self>) {
        let mut waiters = self.waiters.borrow_mut();
        if let Some(w) = waiters.pop_front() {
            w.granted.set(true);
            w.waker.wake();
        } else {
            let f = self.free.get();
            debug_assert!(f < self.servers, "release without acquire on {}", self.name);
            self.free.set(f + 1);
        }
    }

    /// acquire → hold for `dur` → release. The canonical timed service.
    pub async fn serve(self: &Rc<Self>, sim: &Sim, dur: SimTime) {
        self.acquire().await;
        sim.sleep(dur).await;
        self.busy.set(self.busy.get() + dur);
        self.acquires.set(self.acquires.get() + 1);
        self.release();
    }
}

/// Future returned by [`Resource::acquire`].
pub struct Acquire {
    res: Rc<Resource>,
    granted: Rc<Cell<bool>>,
    queued: bool,
}

impl Future for Acquire {
    type Output = ();
    fn poll(mut self: Pin<&mut Self>, cx: &mut Context<'_>) -> Poll<()> {
        if self.granted.get() {
            return Poll::Ready(());
        }
        if !self.queued {
            let free = self.res.free.get();
            if free > 0 {
                self.res.free.set(free - 1);
                return Poll::Ready(());
            }
            self.queued = true;
            self.res.waiters.borrow_mut().push_back(Waiter {
                granted: self.granted.clone(),
                waker: cx.waker().clone(),
            });
        }
        Poll::Pending
    }
}

/// Mutual exclusion = 1-server resource; alias for readability.
pub fn mutex(name: impl Into<String>) -> Rc<Resource> {
    Resource::new(name, 1)
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::cell::RefCell;

    #[test]
    fn single_server_serializes() {
        let sim = Sim::new();
        let res = Resource::new("dev", 1);
        let ends = Rc::new(RefCell::new(Vec::new()));
        for i in 0..3u64 {
            let s = sim.clone();
            let r = res.clone();
            let e = ends.clone();
            sim.spawn(async move {
                r.serve(&s, SimTime::micros(10)).await;
                e.borrow_mut().push((i, s.now()));
            });
        }
        sim.run();
        let ends = ends.borrow();
        // FIFO: finish at 10, 20, 30 us in spawn order
        assert_eq!(ends[0], (0, SimTime::micros(10)));
        assert_eq!(ends[1], (1, SimTime::micros(20)));
        assert_eq!(ends[2], (2, SimTime::micros(30)));
    }

    #[test]
    fn multi_server_parallelism() {
        let sim = Sim::new();
        let res = Resource::new("dev", 2);
        let ends = Rc::new(RefCell::new(Vec::new()));
        for _ in 0..4 {
            let s = sim.clone();
            let r = res.clone();
            let e = ends.clone();
            sim.spawn(async move {
                r.serve(&s, SimTime::micros(10)).await;
                e.borrow_mut().push(s.now());
            });
        }
        let end = sim.run();
        // 4 jobs, 2 servers, 10us each -> makespan 20us
        assert_eq!(end, SimTime::micros(20));
        assert_eq!(ends.borrow().len(), 4);
    }

    #[test]
    fn utilization_accounting() {
        let sim = Sim::new();
        let res = Resource::new("dev", 1);
        let r = res.clone();
        let s = sim.clone();
        sim.spawn(async move {
            r.serve(&s, SimTime::micros(7)).await;
            r.serve(&s, SimTime::micros(3)).await;
        });
        sim.run();
        assert_eq!(res.busy_time(), SimTime::micros(10));
        assert_eq!(res.acquires(), 2);
    }

    #[test]
    fn fifo_order_preserved() {
        let sim = Sim::new();
        let res = Resource::new("q", 1);
        let order = Rc::new(RefCell::new(Vec::new()));
        // occupy the resource first
        {
            let s = sim.clone();
            let r = res.clone();
            sim.spawn(async move {
                r.serve(&s, SimTime::micros(5)).await;
            });
        }
        for i in 0..5u32 {
            let s = sim.clone();
            let r = res.clone();
            let o = order.clone();
            sim.spawn(async move {
                // stagger arrival so queue order is deterministic
                s.sleep(SimTime::nanos(i as u64)).await;
                r.serve(&s, SimTime::micros(1)).await;
                o.borrow_mut().push(i);
            });
        }
        sim.run();
        assert_eq!(*order.borrow(), vec![0, 1, 2, 3, 4]);
    }
}
