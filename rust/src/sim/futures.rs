//! Future combinators for the virtual-time executor (no `futures` crate).

use std::future::Future;
use std::pin::Pin;
use std::task::{Context, Poll};

type BoxFut<'a, T> = Pin<Box<dyn Future<Output = T> + 'a>>;

/// Drive a set of futures concurrently; resolve when all complete.
/// Results are returned in input order.
pub struct JoinAll<'a, T> {
    slots: Vec<Option<BoxFut<'a, T>>>,
    results: Vec<Option<T>>,
}

/// Run all futures to completion concurrently (in virtual time).
pub fn join_all<'a, T: 'a>(futs: Vec<BoxFut<'a, T>>) -> JoinAll<'a, T> {
    let n = futs.len();
    JoinAll {
        slots: futs.into_iter().map(Some).collect(),
        results: (0..n).map(|_| None).collect(),
    }
}

/// Convenience: box a future for `join_all`.
pub fn boxed<'a, T, F: Future<Output = T> + 'a>(f: F) -> BoxFut<'a, T> {
    Box::pin(f)
}

// Safe: JoinAll never projects a pin into `T`; stored futures are boxed.
impl<'a, T> Unpin for JoinAll<'a, T> {}

impl<'a, T> Future for JoinAll<'a, T> {
    type Output = Vec<T>;
    fn poll(self: Pin<&mut Self>, cx: &mut Context<'_>) -> Poll<Vec<T>> {
        // JoinAll is Unpin: it only holds boxed (already-pinned) futures.
        let this = self.get_mut();
        let mut all_done = true;
        for i in 0..this.slots.len() {
            if let Some(f) = this.slots[i].as_mut() {
                match f.as_mut().poll(cx) {
                    Poll::Ready(v) => {
                        this.results[i] = Some(v);
                        this.slots[i] = None;
                    }
                    Poll::Pending => all_done = false,
                }
            }
        }
        if all_done {
            Poll::Ready(this.results.iter_mut().map(|r| r.take().unwrap()).collect())
        } else {
            Poll::Pending
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::exec::Sim;
    use crate::sim::resource::Resource;
    use crate::sim::time::SimTime;
    use std::cell::Cell;
    use std::rc::Rc;

    #[test]
    fn join_all_overlaps_in_time() {
        let sim = Sim::new();
        let s = sim.clone();
        let end = Rc::new(Cell::new(SimTime::ZERO));
        let e = end.clone();
        sim.spawn(async move {
            let futs = (1..=3u64)
                .map(|i| {
                    let s2 = s.clone();
                    boxed(async move {
                        s2.sleep(SimTime::micros(10 * i)).await;
                        i
                    })
                })
                .collect();
            let out = join_all(futs).await;
            assert_eq!(out, vec![1, 2, 3]);
            e.set(s.now());
        });
        sim.run();
        // concurrent, so makespan = max (30us), not sum (60us)
        assert_eq!(end.get(), SimTime::micros(30));
    }

    #[test]
    fn join_all_contends_on_shared_resource() {
        let sim = Sim::new();
        let res = Resource::new("r", 1);
        let s = sim.clone();
        sim.spawn(async move {
            let futs = (0..3)
                .map(|_| {
                    let s2 = s.clone();
                    let r = res.clone();
                    boxed(async move {
                        r.serve(&s2, SimTime::micros(10)).await;
                    })
                })
                .collect();
            join_all(futs).await;
        });
        // serialized by the 1-server resource
        assert_eq!(sim.run(), SimTime::micros(30));
    }

    #[test]
    fn empty_join() {
        let sim = Sim::new();
        sim.spawn(async move {
            let out: Vec<u32> = join_all(vec![]).await;
            assert!(out.is_empty());
        });
        sim.run();
    }
}
