//! Single-threaded virtual-time async executor — the discrete-event engine.
//!
//! Simulated processes are plain `async` blocks spawned on a [`Sim`].
//! The only ways time passes are awaiting [`Sim::sleep`] /
//! [`Sim::sleep_until`] or awaiting a queued resource
//! (see [`crate::sim::resource`]). The run loop repeatedly polls every
//! ready task, then advances the virtual clock to the earliest pending
//! timer. Execution is fully deterministic given the spawn order.
//!
//! This replaces tokio (unavailable offline) and is *faster* for this use
//! case: no syscalls, no atomics on the hot path beyond the waker queue.

use std::cell::{Cell, RefCell};
use std::collections::{BinaryHeap, VecDeque};
use std::future::Future;
use std::pin::Pin;
use std::rc::Rc;
use std::sync::{Arc, Mutex};
use std::task::{Context, Poll, Wake, Waker};

use super::time::SimTime;

type TaskId = u64;
type BoxFuture = Pin<Box<dyn Future<Output = ()>>>;

/// Thread-safe wake queue (wakers must be Send+Sync by contract even though
/// we only ever use them on one thread).
struct WakeQueue {
    ready: Mutex<VecDeque<TaskId>>,
}

struct TaskWaker {
    id: TaskId,
    queue: Arc<WakeQueue>,
}

impl Wake for TaskWaker {
    fn wake(self: Arc<Self>) {
        self.queue.ready.lock().unwrap().push_back(self.id);
    }
    fn wake_by_ref(self: &Arc<Self>) {
        self.queue.ready.lock().unwrap().push_back(self.id);
    }
}

/// Timer entry: min-heap ordered by (deadline, seq) for determinism.
struct Timer {
    deadline: SimTime,
    seq: u64,
    waker: Waker,
}

impl PartialEq for Timer {
    fn eq(&self, other: &Self) -> bool {
        self.deadline == other.deadline && self.seq == other.seq
    }
}
impl Eq for Timer {}
impl PartialOrd for Timer {
    fn partial_cmp(&self, other: &Self) -> Option<std::cmp::Ordering> {
        Some(self.cmp(other))
    }
}
impl Ord for Timer {
    fn cmp(&self, other: &Self) -> std::cmp::Ordering {
        // reversed: BinaryHeap is a max-heap, we want the earliest first
        other
            .deadline
            .cmp(&self.deadline)
            .then(other.seq.cmp(&self.seq))
    }
}

struct SimInner {
    now: Cell<SimTime>,
    timers: RefCell<BinaryHeap<Timer>>,
    /// slab keyed by sequential TaskId (perf: no hashing on the poll path)
    tasks: RefCell<Vec<Option<(BoxFuture, Waker)>>>,
    next_task: Cell<TaskId>,
    timer_seq: Cell<u64>,
    wake_queue: Arc<WakeQueue>,
    live_tasks: Cell<u64>,
    /// Total number of task polls — a cheap engine-throughput metric.
    polls: Cell<u64>,
}

/// Handle to the simulation; cheap to clone, single-threaded.
#[derive(Clone)]
pub struct Sim {
    inner: Rc<SimInner>,
}

impl Default for Sim {
    fn default() -> Self {
        Self::new()
    }
}

impl Sim {
    pub fn new() -> Sim {
        Sim {
            inner: Rc::new(SimInner {
                now: Cell::new(SimTime::ZERO),
                timers: RefCell::new(BinaryHeap::new()),
                tasks: RefCell::new(Vec::new()),
                next_task: Cell::new(0),
                timer_seq: Cell::new(0),
                wake_queue: Arc::new(WakeQueue {
                    ready: Mutex::new(VecDeque::new()),
                }),
                live_tasks: Cell::new(0),
                polls: Cell::new(0),
            }),
        }
    }

    /// Current virtual time.
    pub fn now(&self) -> SimTime {
        self.inner.now.get()
    }

    /// Number of task polls performed so far (engine throughput metric).
    pub fn poll_count(&self) -> u64 {
        self.inner.polls.get()
    }

    /// Spawn a simulated process. It starts running on the next executor turn.
    pub fn spawn<F: Future<Output = ()> + 'static>(&self, fut: F) {
        let id = self.inner.next_task.get();
        self.inner.next_task.set(id + 1);
        // one Waker per task, reused across polls (perf: no per-poll Arc)
        let waker = Waker::from(Arc::new(TaskWaker {
            id,
            queue: self.inner.wake_queue.clone(),
        }));
        {
            let mut tasks = self.inner.tasks.borrow_mut();
            debug_assert_eq!(tasks.len() as u64, id);
            tasks.push(Some((Box::pin(fut), waker)));
        }
        self.inner.live_tasks.set(self.inner.live_tasks.get() + 1);
        self.inner.wake_queue.ready.lock().unwrap().push_back(id);
    }

    /// Sleep for a duration of virtual time.
    pub fn sleep(&self, d: SimTime) -> Sleep {
        self.sleep_until(self.now() + d)
    }

    /// Sleep until an absolute virtual deadline.
    pub fn sleep_until(&self, deadline: SimTime) -> Sleep {
        Sleep {
            sim: self.clone(),
            deadline,
            registered: false,
        }
    }

    /// Yield once (reschedule at the current time, after other ready tasks).
    pub fn yield_now(&self) -> Sleep {
        self.sleep(SimTime::ZERO)
    }

    fn register_timer(&self, deadline: SimTime, waker: Waker) {
        let seq = self.inner.timer_seq.get();
        self.inner.timer_seq.set(seq + 1);
        self.inner.timers.borrow_mut().push(Timer {
            deadline,
            seq,
            waker,
        });
    }

    fn poll_task(&self, id: TaskId) {
        // Take the future out so re-entrant spawn() can't alias the slot.
        let slot = {
            let mut tasks = self.inner.tasks.borrow_mut();
            match tasks.get_mut(id as usize) {
                Some(s) => s.take(),
                None => None,
            }
        };
        let Some((mut fut, waker)) = slot else { return };
        let mut cx = Context::from_waker(&waker);
        self.inner.polls.set(self.inner.polls.get() + 1);
        match fut.as_mut().poll(&mut cx) {
            Poll::Ready(()) => {
                self.inner.live_tasks.set(self.inner.live_tasks.get() - 1);
            }
            Poll::Pending => {
                self.inner.tasks.borrow_mut()[id as usize] = Some((fut, waker));
            }
        }
    }

    /// Run until all spawned tasks complete. Returns the final virtual time.
    ///
    /// Panics on deadlock (live tasks but no timers and nothing ready),
    /// which in practice means a resource was acquired and never released.
    pub fn run(&self) -> SimTime {
        loop {
            // Drain the ready queue.
            loop {
                let next = self.inner.wake_queue.ready.lock().unwrap().pop_front();
                match next {
                    Some(id) => self.poll_task(id),
                    None => break,
                }
            }
            if self.inner.live_tasks.get() == 0 {
                return self.now();
            }
            // Advance virtual time to the earliest timer.
            let timer = self.inner.timers.borrow_mut().pop();
            match timer {
                Some(t) => {
                    debug_assert!(t.deadline >= self.now());
                    self.inner.now.set(t.deadline);
                    t.waker.wake();
                }
                None => {
                    panic!(
                        "sim deadlock: {} live task(s) but no pending timers",
                        self.inner.live_tasks.get()
                    );
                }
            }
        }
    }
}

/// Future returned by [`Sim::sleep`].
pub struct Sleep {
    sim: Sim,
    deadline: SimTime,
    registered: bool,
}

impl Future for Sleep {
    type Output = ();
    fn poll(mut self: Pin<&mut Self>, cx: &mut Context<'_>) -> Poll<()> {
        if self.sim.now() >= self.deadline {
            return Poll::Ready(());
        }
        if !self.registered {
            self.registered = true;
            let deadline = self.deadline;
            self.sim.register_timer(deadline, cx.waker().clone());
        }
        Poll::Pending
    }
}

/// Completion latch: lets one task wait for N others (like a WaitGroup).
pub struct WaitGroup {
    count: Cell<usize>,
    wakers: RefCell<Vec<Waker>>,
}

impl WaitGroup {
    pub fn new(count: usize) -> Rc<WaitGroup> {
        Rc::new(WaitGroup {
            count: Cell::new(count),
            wakers: RefCell::new(Vec::new()),
        })
    }

    /// Signal one completion.
    pub fn done(&self) {
        let c = self.count.get();
        assert!(c > 0, "WaitGroup::done called too many times");
        self.count.set(c - 1);
        if c == 1 {
            for w in self.wakers.borrow_mut().drain(..) {
                w.wake();
            }
        }
    }

    /// Wait until the counter reaches zero.
    pub fn wait(self: &Rc<Self>) -> WaitFut {
        WaitFut { wg: self.clone() }
    }
}

pub struct WaitFut {
    wg: Rc<WaitGroup>,
}

impl Future for WaitFut {
    type Output = ();
    fn poll(self: Pin<&mut Self>, cx: &mut Context<'_>) -> Poll<()> {
        if self.wg.count.get() == 0 {
            Poll::Ready(())
        } else {
            self.wg.wakers.borrow_mut().push(cx.waker().clone());
            Poll::Pending
        }
    }
}

/// One-shot cell a task can park on until a value is produced.
pub struct OnceCellFut<T> {
    value: RefCell<Option<T>>,
    wakers: RefCell<Vec<Waker>>,
}

impl<T: Clone> OnceCellFut<T> {
    pub fn new() -> Rc<Self> {
        Rc::new(OnceCellFut {
            value: RefCell::new(None),
            wakers: RefCell::new(Vec::new()),
        })
    }

    pub fn set(&self, v: T) {
        *self.value.borrow_mut() = Some(v);
        for w in self.wakers.borrow_mut().drain(..) {
            w.wake();
        }
    }

    pub async fn get(self: &Rc<Self>) -> T {
        GetFut { cell: self.clone() }.await
    }
}

struct GetFut<T> {
    cell: Rc<OnceCellFut<T>>,
}

impl<T: Clone> Future for GetFut<T> {
    type Output = T;
    fn poll(self: Pin<&mut Self>, cx: &mut Context<'_>) -> Poll<T> {
        if let Some(v) = self.cell.value.borrow().as_ref() {
            return Poll::Ready(v.clone());
        }
        self.cell.wakers.borrow_mut().push(cx.waker().clone());
        Poll::Pending
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::rc::Rc;

    #[test]
    fn sleep_advances_clock() {
        let sim = Sim::new();
        let s = sim.clone();
        sim.spawn(async move {
            s.sleep(SimTime::micros(10)).await;
            assert_eq!(s.now(), SimTime::micros(10));
            s.sleep(SimTime::micros(5)).await;
            assert_eq!(s.now(), SimTime::micros(15));
        });
        let end = sim.run();
        assert_eq!(end, SimTime::micros(15));
    }

    #[test]
    fn concurrent_tasks_interleave_by_time() {
        let sim = Sim::new();
        let order = Rc::new(RefCell::new(Vec::new()));
        for (i, d) in [(0u32, 30u64), (1, 10), (2, 20)] {
            let s = sim.clone();
            let ord = order.clone();
            sim.spawn(async move {
                s.sleep(SimTime::micros(d)).await;
                ord.borrow_mut().push(i);
            });
        }
        sim.run();
        assert_eq!(*order.borrow(), vec![1, 2, 0]);
    }

    #[test]
    fn waitgroup_joins() {
        let sim = Sim::new();
        let wg = WaitGroup::new(3);
        for i in 0..3u64 {
            let s = sim.clone();
            let wg = wg.clone();
            sim.spawn(async move {
                s.sleep(SimTime::micros(i + 1)).await;
                wg.done();
            });
        }
        let s = sim.clone();
        let wg2 = wg.clone();
        let done_at = Rc::new(Cell::new(SimTime::ZERO));
        let done_at2 = done_at.clone();
        sim.spawn(async move {
            wg2.wait().await;
            done_at2.set(s.now());
        });
        sim.run();
        assert_eq!(done_at.get(), SimTime::micros(3));
    }

    #[test]
    fn spawn_from_task() {
        let sim = Sim::new();
        let s = sim.clone();
        let hits = Rc::new(Cell::new(0u32));
        let h = hits.clone();
        sim.spawn(async move {
            let h2 = h.clone();
            let s2 = s.clone();
            s.spawn(async move {
                s2.sleep(SimTime::micros(1)).await;
                h2.set(h2.get() + 1);
            });
            h.set(h.get() + 1);
        });
        sim.run();
        assert_eq!(hits.get(), 2);
    }

    #[test]
    #[should_panic(expected = "sim deadlock")]
    fn deadlock_detected() {
        let sim = Sim::new();
        let cell: Rc<OnceCellFut<u32>> = OnceCellFut::new();
        sim.spawn(async move {
            let _ = cell.get().await; // never set
        });
        sim.run();
    }

    #[test]
    fn once_cell_delivers() {
        let sim = Sim::new();
        let cell: Rc<OnceCellFut<u32>> = OnceCellFut::new();
        let c1 = cell.clone();
        let s = sim.clone();
        sim.spawn(async move {
            s.sleep(SimTime::micros(2)).await;
            c1.set(7);
        });
        let got = Rc::new(Cell::new(0u32));
        let g = got.clone();
        sim.spawn(async move {
            g.set(cell.get().await);
        });
        sim.run();
        assert_eq!(got.get(), 7);
    }

    #[test]
    fn zero_sleep_yields() {
        let sim = Sim::new();
        let s = sim.clone();
        sim.spawn(async move {
            s.yield_now().await;
            s.yield_now().await;
        });
        assert_eq!(sim.run(), SimTime::ZERO);
    }
}
