//! Durability-subsystem property tests: WAL replay idempotence on the
//! POSIX catalogue, seeded fault schedules over the full recursive
//! wrapper composition (every op either fails with a typed `FdbError`
//! or round-trips byte-identical), and the `ReplicatedStore` mid-batch
//! `read_ranges` failover regression under injected read faults.

use std::cell::{Cell, RefCell};
use std::rc::Rc;

use fdbr::bench::hammer::{field_id as hammer_id, field_seed};
use fdbr::bench::scenario::{deploy, Deployment, RedundancyOpt, SystemKind, SystemUnderTest};
use fdbr::fdb::backend::{NullStore, Store};
use fdbr::fdb::fault::{FaultAction, FaultClass};
use fdbr::fdb::wrappers::{ReadPolicy, ReplicatedStore};
use fdbr::fdb::{
    BackendConfig, DataHandle, FaultPlan, FaultStore, FdbBuilder, FdbError, IoProfile, Key,
};
use fdbr::hw::profiles::Testbed;
use fdbr::sim::exec::Sim;
use fdbr::util::content::Bytes;

fn field(i: usize) -> Key {
    hammer_id(0, 1 + (i / 8) as u32, (i % 8) as u32, 0)
}

/// A durable writer on a Lustre deployment archives `nfields` fields,
/// is fail-stopped by a seeded fault after `kill` store writes, and is
/// dropped without flush or close — a crashed producer. Returns the
/// (fault-cleared) deployment, the attempted ids, and how many fields
/// the writer archived before dying.
fn crash_writer(seed: u64, kill: u64, nfields: usize) -> (Deployment, Vec<Key>, usize) {
    let plan =
        FaultPlan::new(seed).with_rule(FaultClass::Write, FaultAction::FailStop { after: kill });
    let mut dep = deploy(Testbed::Gcp, SystemKind::Lustre, 2, 2, RedundancyOpt::None)
        .with_io(IoProfile::default().with_durable(true))
        .with_fault(plan);
    let nodes = dep.client_nodes();
    let ids: Vec<Key> = (0..nfields).map(field).collect();
    let mut w = dep.fdb(&nodes[0]);
    let archived = Rc::new(RefCell::new(0usize));
    {
        let ids = ids.clone();
        let archived = archived.clone();
        dep.sim.spawn(async move {
            for (i, id) in ids.iter().enumerate() {
                let data = Bytes::virt(2048, field_seed(id));
                if w.archive(id, data).await.is_err() {
                    break;
                }
                *archived.borrow_mut() = i + 1;
            }
            drop(w); // the in-memory index dies with the process
        });
        dep.sim.run();
    }
    dep.fault = None;
    let archived = *archived.borrow();
    (dep, ids, archived)
}

#[test]
fn wal_replay_is_idempotent_for_a_durable_recoverer() {
    // a durable recoverer replays the dead writer's WAL (re-journaling
    // each intent under its own log) and retires the foreign WAL; a
    // second recover pass must find nothing left to do and the visible
    // dataset must not change
    let (dep, ids, archived) = crash_writer(0xA11CE, 9, 16);
    assert_eq!(archived, 9, "fail-stop after 9 writes");
    let nodes = dep.client_nodes();
    let mut rec = dep.fdb(&nodes[1]);
    let ds = ids[0].project(&rec.schema.dataset.clone()).unwrap();
    let out = Rc::new(RefCell::new((0usize, 0usize, 0usize, 0usize)));
    {
        let out = out.clone();
        let ids = ids.clone();
        dep.sim.spawn(async move {
            let stats1 = rec.recover(&ds).await.expect("first recover");
            rec.flush().await.expect("publish");
            rec.invalidate_preload(&ds);
            let mut found1 = 0;
            for id in &ids {
                if rec.retrieve(id).await.expect("retrieve").is_some() {
                    found1 += 1;
                }
            }
            let stats2 = rec.recover(&ds).await.expect("second recover");
            rec.flush().await.expect("publish again");
            rec.invalidate_preload(&ds);
            let mut found2 = 0;
            for id in &ids {
                if rec.retrieve(id).await.expect("retrieve").is_some() {
                    found2 += 1;
                }
            }
            *out.borrow_mut() = (stats1.replayed, stats2.replayed, found1, found2);
        });
        dep.sim.run();
    }
    let (replayed1, replayed2, found1, found2) = *out.borrow();
    assert_eq!(replayed1, archived, "first pass replays every intent");
    assert_eq!(replayed2, 0, "replayed WAL was retired: second pass is a no-op");
    assert_eq!(found1, archived);
    assert_eq!(found2, archived, "double recovery must not change the dataset");
}

#[test]
fn wal_replay_converges_for_a_non_durable_recoverer() {
    // without the durable knob the recoverer keeps the old WAL (its own
    // replay is not journaled, so retiring the log would reopen the
    // crash window). Replaying the same intents twice must converge to
    // the same byte-identical dataset — index inserts are keyed, not
    // appended
    let (mut dep, ids, archived) = crash_writer(0xBEEF, 6, 12);
    assert_eq!(archived, 6);
    dep.io.durable = false;
    let nodes = dep.client_nodes();
    let mut rec = dep.fdb(&nodes[1]);
    let ds = ids[0].project(&rec.schema.dataset.clone()).unwrap();
    let out = Rc::new(RefCell::new((0usize, 0usize, 0usize, 0usize)));
    {
        let out = out.clone();
        let ids = ids.clone();
        dep.sim.spawn(async move {
            let stats1 = rec.recover(&ds).await.expect("first recover");
            rec.flush().await.expect("publish");
            let stats2 = rec.recover(&ds).await.expect("second recover");
            rec.flush().await.expect("publish again");
            rec.invalidate_preload(&ds);
            let mut verified = 0;
            let mut ghosts = 0;
            for (i, id) in ids.iter().enumerate() {
                match rec.retrieve(id).await.expect("retrieve") {
                    Some(h) => {
                        if i >= archived {
                            ghosts += 1;
                            continue;
                        }
                        let got = rec.read(&h).await.expect("read");
                        if got.content_eq(&Bytes::virt(2048, field_seed(id))) {
                            verified += 1;
                        }
                    }
                    None => {}
                }
            }
            *out.borrow_mut() = (stats1.replayed, stats2.replayed, verified, ghosts);
        });
        dep.sim.run();
    }
    let (replayed1, replayed2, verified, ghosts) = *out.borrow();
    assert_eq!(replayed1, archived);
    assert_eq!(replayed2, archived, "the kept WAL replays again");
    assert_eq!(verified, archived, "double replay still byte-identical");
    assert_eq!(ghosts, 0, "nothing past the kill point may surface");
}

#[test]
fn fault_schedules_over_nested_composition_are_typed_or_byte_identical() {
    // property: under seeded probabilistic faults injected both around
    // the whole `sharded(tiered(posix, replicated(posix)))` composition
    // AND inside each replica, every operation either returns a typed
    // FdbError or completes; every field whose archive reported Ok
    // round-trips byte-identical through a fault-free observer
    let mut total_errored = 0usize;
    let mut total_verified = 0usize;
    for seed in [1u64, 2, 3, 4] {
        let dep = deploy(Testbed::Gcp, SystemKind::Lustre, 2, 2, RedundancyOpt::None);
        let SystemUnderTest::Lustre(fs) = &dep.system else {
            unreachable!()
        };
        let posix = |root: &str| BackendConfig::Posix {
            fs: fs.clone(),
            root: root.to_string(),
        };
        let plan = FaultPlan::parse(&format!(
            "seed={seed},err:write:p0.2,err:read:p0.2,err:flush:p0.15,err:index:p0.1"
        ))
        .unwrap();
        let nested = |faulty: bool| -> BackendConfig {
            let replica = if faulty {
                BackendConfig::Fault {
                    inner: Box::new(posix("/fdb")),
                    plan: plan.clone(),
                }
            } else {
                posix("/fdb")
            };
            let base = BackendConfig::Sharded {
                inner: Box::new(BackendConfig::Tiered {
                    front: Box::new(posix("/scm")),
                    back: Box::new(BackendConfig::Replicated {
                        inner: Box::new(replica),
                        copies: 2,
                    }),
                }),
                shards: 2,
            };
            if faulty {
                BackendConfig::Fault {
                    inner: Box::new(base),
                    plan: plan.clone(),
                }
            } else {
                base
            }
        };
        let nodes = dep.client_nodes();
        let mut w = FdbBuilder::new(&dep.sim)
            .node(&nodes[0])
            .backend(nested(true))
            .build()
            .unwrap();
        let mut r = FdbBuilder::new(&dep.sim)
            .node(&nodes[1])
            .backend(nested(false))
            .build()
            .unwrap();
        let counts = Rc::new(RefCell::new((0usize, 0usize)));
        {
            let counts = counts.clone();
            dep.sim.spawn(async move {
                let typed = |e: &FdbError| {
                    matches!(
                        e,
                        FdbError::Backend { .. } | FdbError::AllReplicasFailed { .. }
                    )
                };
                let mut expected: Vec<(Key, Bytes)> = Vec::new();
                for i in 0..24usize {
                    let id = field(i);
                    let data = Bytes::virt(512 + 131 * i as u64, seed * 1000 + i as u64);
                    match w.archive(&id, data.clone()).await {
                        Ok(()) => expected.push((id, data)),
                        Err(e) => {
                            assert!(typed(&e), "untyped archive error: {e}");
                            counts.borrow_mut().0 += 1;
                        }
                    }
                }
                // publishing is fault-injected too: bounded retry until
                // one flush passes every gate
                let mut tries = 0;
                while let Err(e) = w.flush().await {
                    assert!(typed(&e), "untyped flush error: {e}");
                    tries += 1;
                    assert!(tries < 200, "flush never succeeded");
                }
                for (id, data) in &expected {
                    let h = r
                        .retrieve(id)
                        .await
                        .expect("fault-free retrieve")
                        .expect("archived field must be indexed");
                    let got = r.read(&h).await.expect("fault-free read");
                    assert!(got.content_eq(data), "bytes differ for {id}");
                    counts.borrow_mut().1 += 1;
                }
            });
            dep.sim.run();
        }
        let (errored, verified) = *counts.borrow();
        assert_eq!(errored + verified, 24, "every op accounted for (seed {seed})");
        total_errored += errored;
        total_verified += verified;
    }
    // the property must not hold vacuously: across the seeds, some ops
    // failed and some round-tripped
    assert!(total_errored > 0, "no fault ever fired");
    assert!(total_verified > 0, "no field ever round-tripped");
}

#[test]
fn replicated_read_ranges_fails_over_mid_batch() {
    // regression for the per-range failover on the vectored read path:
    // replica 0 fail-stops in the middle of a 10-range batch and the
    // wrapper must finish the batch from replica 1, order and lengths
    // intact — never a short or reordered result
    fn mk(kill: u64) -> ReplicatedStore {
        let plan = FaultPlan::new(0xF0)
            .with_rule(FaultClass::Read, FaultAction::FailStop { after: kill });
        ReplicatedStore::new(vec![
            Box::new(FaultStore::new(Box::new(NullStore), plan.build_state(None))),
            Box::new(FaultStore::new(Box::new(NullStore), plan.build_state(None))),
        ])
        .with_read_policy(ReadPolicy::FirstHealthy)
    }
    let handles: Vec<DataHandle> = (0..10u64)
        .map(|i| DataHandle::Null { length: 100 + i })
        .collect();

    // kill after 6 reads: replica 0 serves ranges 0..6, dies at range 6,
    // and replica 1 (4 reads, under its own budget) finishes the batch
    let sim = Sim::new();
    let ok = Rc::new(Cell::new(false));
    {
        let ok = ok.clone();
        let handles = handles.clone();
        sim.spawn(async move {
            let mut rep = mk(6);
            let out = rep.read_ranges(&handles).await.expect("failover completes");
            assert_eq!(out.len(), 10);
            for (i, bytes) in out.iter().enumerate() {
                assert_eq!(bytes.len(), 100 + i as u64, "range {i} length");
            }
            ok.set(true);
        });
        sim.run();
    }
    assert!(ok.get());

    // kill after 3: both replicas exhaust their read budgets before the
    // batch ends — the whole batch fails with the typed replica error
    let sim = Sim::new();
    let ok = Rc::new(Cell::new(false));
    {
        let ok = ok.clone();
        sim.spawn(async move {
            let mut rep = mk(3);
            let err = rep.read_ranges(&handles).await.unwrap_err();
            match err {
                FdbError::AllReplicasFailed { op, copies, .. } => {
                    assert_eq!(op, "read");
                    assert_eq!(copies, 2);
                }
                other => panic!("expected AllReplicasFailed, got {other}"),
            }
            ok.set(true);
        });
        sim.run();
    }
    assert!(ok.get());
}
