//! Resilience-layer property tests: under a seeded transient read-error
//! storm the engine's retry budget bounds the total ops issued, a
//! recovered retrieve is byte-identical to the no-fault baseline, and
//! the admission semaphore still caps in-flight ops while hedged reads
//! race below it.

use std::cell::RefCell;
use std::rc::Rc;

use fdbr::bench::hammer::{field_id as hammer_id, field_seed};
use fdbr::bench::scenario::{deploy, RedundancyOpt, SystemKind, WrapperOpt};
use fdbr::fdb::{FaultPlan, FdbError, IoProfile, Key, MetricsRegistry, ResilienceProfile};
use fdbr::hw::profiles::Testbed;
use fdbr::util::content::Bytes;

const FIELD: u64 = 4096;

fn field(i: usize) -> Key {
    hammer_id(0, 1 + (i / 16) as u32, (i % 16) as u32, 0)
}

/// Archive `nfields` on a replicated Lustre deployment, publish, then
/// retrieve the whole set from a second node under `fault` (a spec for
/// the per-replica fault wrapper) and `res`. Returns the retrieve
/// outcome; `reg` collects the run's telemetry.
fn run_storm(
    copies: usize,
    fault: Option<&str>,
    res: Option<ResilienceProfile>,
    depth: usize,
    nfields: usize,
    reg: &MetricsRegistry,
) -> Result<Vec<(Key, Bytes)>, FdbError> {
    let mut dep = deploy(Testbed::Gcp, SystemKind::Lustre, 2, 2, RedundancyOpt::None)
        .with_wrapper(WrapperOpt::Replicated(copies))
        .with_io(IoProfile::depth(depth).with_preload_indexes(true))
        .with_metrics(reg);
    if let Some(spec) = fault {
        dep = dep.with_fault(FaultPlan::parse(spec).expect("fault spec"));
    }
    if let Some(r) = res {
        dep = dep.with_resilience(r);
    }
    let nodes = dep.client_nodes();
    let ids: Vec<Key> = (0..nfields).map(field).collect();

    let mut w = dep.fdb(&nodes[0]);
    let batch: Vec<(Key, Bytes)> = ids
        .iter()
        .map(|id| (id.clone(), Bytes::virt(FIELD, field_seed(id))))
        .collect();
    dep.sim.spawn(async move {
        w.archive_many(batch).await.expect("storm is read-class");
        w.flush().await.expect("publish");
        w.close().await.expect("close");
    });
    dep.sim.run();

    let mut r = dep.fdb(&nodes[1]);
    let out = Rc::new(RefCell::new(None));
    {
        let out = out.clone();
        let ids = ids.clone();
        dep.sim.spawn(async move {
            *out.borrow_mut() = Some(r.retrieve_many(&ids).await);
        });
        dep.sim.run();
    }
    let got = out.borrow_mut().take().expect("reader ran");
    got
}

#[test]
fn retry_budget_bounds_total_issued_ops() {
    // property: with a max-attempts budget of A over F fields, the
    // engine never issues more than A ops per admitted read — so
    // first attempts + retries stays within A x ops (and ops <= F:
    // coalescing can merge reads, never multiply 4 KiB fields)
    let nfields = 48usize;
    let res = ResilienceProfile::retries(5).with_backoff_us(100).with_seed(3);
    let reg = MetricsRegistry::new();
    let fetched = run_storm(
        3,
        Some("seed=9,err:read:p0.5:transient"),
        Some(res),
        4,
        nfields,
        &reg,
    )
    .expect("a 5-attempt budget over 3 replicas absorbs a p0.5 storm");
    assert_eq!(fetched.len(), nfields, "every published field found");

    let ops = reg
        .hist("engine.service.data-read")
        .expect("data reads ran")
        .count();
    let retries = reg.counter_value("engine.retry.attempts");
    assert!(ops >= 1);
    assert!(ops <= nfields as u64, "coalescing never multiplies ops");
    assert!(
        retries >= 1,
        "a p0.5 storm over {nfields} fields must trigger at least one retry"
    );
    assert!(
        ops + retries <= 5 * ops,
        "issued ops ({ops} + {retries} retries) exceed the 5-attempt budget"
    );
    assert!(
        ops + retries <= 5 * nfields as u64,
        "issued ops exceed attempts-budget x fields"
    );
    assert!(
        reg.counter_value("engine.retry.recovered") >= 1,
        "recovered retries must be counted"
    );
    assert_eq!(
        reg.counter_value("engine.retry.exhausted"),
        0,
        "nothing exhausted the budget in this run"
    );
}

#[test]
fn recovered_reads_are_byte_identical_to_the_no_fault_baseline() {
    // property: when the retry layer recovers every read, the caller
    // cannot tell the storm happened — same ids, same bytes, same
    // order as the identical workload with no fault injected
    let nfields = 32usize;
    let res = ResilienceProfile::retries(5).with_backoff_us(100).with_seed(3);
    let base_reg = MetricsRegistry::new();
    let baseline = run_storm(3, None, Some(res), 4, nfields, &base_reg).expect("no faults");
    let storm_reg = MetricsRegistry::new();
    let stormed = run_storm(
        3,
        Some("seed=9,err:read:p0.5:transient"),
        Some(res),
        4,
        nfields,
        &storm_reg,
    )
    .expect("recovered");

    assert_eq!(baseline.len(), nfields);
    assert_eq!(stormed.len(), baseline.len());
    for ((bid, bdata), (sid, sdata)) in baseline.iter().zip(stormed.iter()) {
        assert_eq!(bid, sid, "retrieve order must match the baseline");
        assert!(sdata.content_eq(bdata), "bytes differ for {sid}");
        let expect = Bytes::virt(FIELD, field_seed(sid));
        assert!(sdata.content_eq(&expect), "bytes differ from ground truth");
    }
    assert_eq!(base_reg.counter_value("engine.retry.attempts"), 0);
    assert!(storm_reg.counter_value("engine.retry.attempts") >= 1);
}

#[test]
fn inflight_peak_respects_depth_with_hedges_in_flight() {
    // property: hedged replica reads race INSIDE one admitted engine op,
    // so the admission semaphore's observed peak stays within the
    // configured depth even while hedges are launching
    let depth = 4usize;
    let res = ResilienceProfile::retries(3)
        .with_backoff_us(100)
        .with_seed(3)
        .with_hedge_us(50);
    let reg = MetricsRegistry::new();
    let fetched = run_storm(
        2,
        Some("seed=5,err:read:p0.3:transient"),
        Some(res),
        depth,
        48,
        &reg,
    )
    .expect("recovered");
    assert_eq!(fetched.len(), 48);
    assert!(
        reg.counter_value("engine.hedge.launched") >= 1,
        "a 50us hedge delay under an error storm must launch hedges"
    );
    let peak = reg.gauge_value("engine.inflight_peak");
    assert!(peak >= 1, "the run must record an in-flight peak");
    assert!(
        peak <= depth as u64,
        "in-flight peak {peak} exceeds the configured depth {depth}"
    );
}
