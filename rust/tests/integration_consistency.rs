//! Cross-backend consistency integration tests: the FDB ACID semantics
//! (thesis §2.7) hold on every Store/Catalogue pair, under parallelism
//! and write+read contention, with byte-exact verification.

use std::cell::RefCell;
use std::rc::Rc;

use fdbr::bench::scenario::{deploy, RedundancyOpt, SystemKind, SystemUnderTest};
use fdbr::fdb::{BackendConfig, Fdb, FdbBuilder, Key, Request};
use fdbr::hw::profiles::Testbed;
use fdbr::sim::exec::WaitGroup;
use fdbr::util::content::Bytes;

fn make_fdb(dep: &fdbr::bench::scenario::Deployment, node_idx: usize) -> Fdb {
    let node = dep.client_nodes()[node_idx].clone();
    dep.fdb(&node)
}

fn id_for(member: usize, step: u32, param: u32) -> Key {
    Key::of(&[
        ("class", "od"),
        ("expver", "0001"),
        ("stream", "oper"),
        ("date", "20231201"),
        ("time", "1200"),
        ("type", "ef"),
        ("levtype", "sfc"),
        ("levelist", "1"),
    ])
    .with("number", member.to_string())
    .with("step", step.to_string())
    .with("param", format!("p{param}"))
}

fn seed_of(id: &Key) -> u64 {
    fdbr::ceph::hash_name(&id.canonical())
}

/// 8 parallel writers × 40 fields each; all fields byte-verified by 8
/// parallel readers afterwards. Exercises TOC/index contention paths.
#[test]
fn parallel_writers_then_readers_all_backends() {
    for kind in [SystemKind::Lustre, SystemKind::Daos, SystemKind::Ceph] {
        let dep = deploy(Testbed::Gcp, kind, 2, 4, RedundancyOpt::None);
        let nwriters = 8;
        let wg = WaitGroup::new(nwriters);
        for w in 0..nwriters {
            let mut fdb = make_fdb(&dep, w % 4);
            let wg = wg.clone();
            dep.sim.spawn(async move {
                for step in 1..=5u32 {
                    for param in 0..8 {
                        let id = id_for(w, step, param);
                        fdb.archive(&id, Bytes::virt(64 << 10, seed_of(&id)))
                            .await
                            .unwrap();
                    }
                    fdb.flush().await.expect("flush");
                }
                fdb.close().await.expect("close");
                wg.done();
            });
        }
        dep.sim.run();
        // readers verify everything
        let failures = Rc::new(RefCell::new(Vec::new()));
        for r in 0..nwriters {
            let mut fdb = make_fdb(&dep, (r + 1) % 4);
            let failures = failures.clone();
            dep.sim.spawn(async move {
                for step in 1..=5u32 {
                    for param in 0..8 {
                        let id = id_for(r, step, param);
                        match fdb.retrieve(&id).await.unwrap() {
                            None => failures.borrow_mut().push(format!("missing {id}")),
                            Some(h) => {
                                let data = fdb.read(&h).await.unwrap();
                                if !data.content_eq(&Bytes::virt(64 << 10, seed_of(&id))) {
                                    failures
                                        .borrow_mut()
                                        .push(format!("bytes differ for {id}"));
                                }
                            }
                        }
                    }
                }
            });
        }
        dep.sim.run();
        assert!(
            failures.borrow().is_empty(),
            "{kind:?}: {:?}",
            failures.borrow()
        );
    }
}

/// Concurrent writer + reader on the SAME identifiers: the reader must
/// see either nothing (not yet visible) or complete, correct bytes —
/// never torn data (ACID item 1).
#[test]
fn no_torn_reads_under_live_contention() {
    for kind in [SystemKind::Daos, SystemKind::Ceph] {
        let dep = deploy(Testbed::Gcp, kind, 2, 2, RedundancyOpt::None);
        let mut w = make_fdb(&dep, 0);
        let mut r = make_fdb(&dep, 1);
        let hits = Rc::new(RefCell::new((0u32, 0u32))); // (found, missing)
        let h2 = hits.clone();
        dep.sim.spawn(async move {
            for step in 1..=20u32 {
                let id = id_for(0, step, 0);
                w.archive(&id, Bytes::virt(256 << 10, seed_of(&id)))
                    .await
                    .unwrap();
            }
        });
        let sim = dep.sim.clone();
        dep.sim.spawn(async move {
            for step in 1..=20u32 {
                // poll while the writer runs (first ~7 ms are the
                // writer's pool-connect + container-create ramp)
                sim.sleep(fdbr::sim::time::SimTime::millis(2)).await;
                let id = id_for(0, step, 0);
                // fresh view per poll, like a new PGEN job (pre-loaded
                // axes are a point-in-time snapshot — thesis §3.1.2)
                let ds = id.project(&r.schema.dataset.clone()).unwrap();
                r.invalidate_preload(&ds);
                match r.retrieve(&id).await.unwrap() {
                    None => h2.borrow_mut().1 += 1,
                    Some(h) => {
                        let data = r.read(&h).await.unwrap();
                        assert!(
                            data.content_eq(&Bytes::virt(256 << 10, seed_of(&id))),
                            "{kind:?}: torn read for {id}"
                        );
                        h2.borrow_mut().0 += 1;
                    }
                }
            }
        });
        dep.sim.run();
        let (found, _missing) = *hits.borrow();
        assert!(found > 0, "{kind:?}: reader should observe some fields");
    }
}

/// Re-archiving an identifier replaces it transactionally on every
/// backend (ACID item 5); list() reports exactly one entry per id.
#[test]
fn rearchive_replaces_and_list_deduplicates() {
    for kind in [SystemKind::Lustre, SystemKind::Daos, SystemKind::Ceph] {
        let dep = deploy(Testbed::Gcp, kind, 2, 2, RedundancyOpt::None);
        let mut w = make_fdb(&dep, 0);
        dep.sim.spawn(async move {
            let id = id_for(0, 1, 0);
            w.archive(&id, b"version-one").await.unwrap();
            w.flush().await.expect("flush");
            w.archive(&id, b"version-two!").await.unwrap();
            w.flush().await.expect("flush");
            w.close().await.expect("close");
        });
        dep.sim.run();
        let mut r = make_fdb(&dep, 1);
        let kind2 = kind;
        dep.sim.spawn(async move {
            let id = id_for(0, 1, 0);
            let h = r.retrieve(&id).await.unwrap().expect("found");
            assert_eq!(
                r.read(&h).await.unwrap().to_vec(),
                b"version-two!",
                "{kind2:?}: newest version wins"
            );
            let ds = id.project(&r.schema.dataset.clone()).unwrap();
            let listed = r.list(&ds, &Request::parse("").unwrap()).await;
            assert_eq!(listed.len(), 1, "{kind2:?}: list must deduplicate");
        });
        dep.sim.run();
    }
}

/// POSIX-only: flush() is the visibility barrier; sub-TOC masking after
/// close() keeps results identical.
#[test]
fn posix_flush_visibility_and_masking() {
    let dep = deploy(
        Testbed::NextGenIo,
        SystemKind::Lustre,
        2,
        2,
        RedundancyOpt::None,
    );
    let mut w = make_fdb(&dep, 0);
    let dep_sim = dep.sim.clone();
    let SystemUnderTest::Lustre(fs) = &dep.system else {
        unreachable!()
    };
    let fs = fs.clone();
    let node1 = dep.client_nodes()[1].clone();
    dep.sim.spawn(async move {
        let id = id_for(3, 7, 2);
        w.archive(&id, b"masked-payload").await.unwrap();
        // before flush: a fresh reader sees nothing
        let mut r1 = FdbBuilder::new(&dep_sim)
            .node(&node1)
            .backend(BackendConfig::Posix {
                fs: fs.clone(),
                root: "/fdb".to_string(),
            })
            .build()
            .unwrap();
        assert!(r1.retrieve(&id).await.unwrap().is_none());
        w.flush().await.expect("flush");
        // after flush (partial index via sub-TOC): visible
        let mut r2 = FdbBuilder::new(&dep_sim)
            .node(&node1)
            .backend(BackendConfig::Posix {
                fs: fs.clone(),
                root: "/fdb".to_string(),
            })
            .build()
            .unwrap();
        assert!(r2.retrieve(&id).await.unwrap().is_some());
        w.close().await.expect("close");
        // after close (full index + mask): still exactly one result
        let mut r3 = FdbBuilder::new(&dep_sim)
            .node(&node1)
            .backend(BackendConfig::Posix {
                fs: fs.clone(),
                root: "/fdb".to_string(),
            })
            .build()
            .unwrap();
        let h = r3.retrieve(&id).await.unwrap().expect("still visible");
        assert_eq!(r3.read(&h).await.unwrap().to_vec(), b"masked-payload");
        let ds = id.project(&r3.schema.dataset.clone()).unwrap();
        let listed = r3.list(&ds, &Request::parse("").unwrap()).await;
        assert_eq!(listed.len(), 1, "masking prevents duplicates");
    });
    dep.sim.run();
}

/// Failure injection: a writer that never flushes nor closes must leave
/// the dataset readable (its flushed steps) and consistent.
#[test]
fn crashed_writer_leaves_consistent_dataset() {
    let dep = deploy(Testbed::Gcp, SystemKind::Lustre, 2, 2, RedundancyOpt::None);
    let mut w = make_fdb(&dep, 0);
    dep.sim.spawn(async move {
        // step 1 flushed
        for param in 0..4 {
            let id = id_for(0, 1, param);
            w.archive(&id, Bytes::virt(8 << 10, seed_of(&id)))
                .await
                .unwrap();
        }
        w.flush().await.expect("flush");
        // step 2 archived but NEVER flushed — then the process "dies"
        for param in 0..4 {
            let id = id_for(0, 2, param);
            w.archive(&id, Bytes::virt(8 << 10, seed_of(&id)))
                .await
                .unwrap();
        }
        drop(w); // no flush, no close
    });
    dep.sim.run();
    let mut r = make_fdb(&dep, 1);
    dep.sim.spawn(async move {
        // step 1 fully present and correct
        for param in 0..4 {
            let id = id_for(0, 1, param);
            let h = r
                .retrieve(&id)
                .await
                .unwrap()
                .expect("flushed step visible");
            assert!(r
                .read(&h)
                .await
                .unwrap()
                .content_eq(&Bytes::virt(8 << 10, seed_of(&id))));
        }
        // step 2 invisible (never flushed): cache semantics, not an error
        for param in 0..4 {
            let id = id_for(0, 2, param);
            assert!(r.retrieve(&id).await.unwrap().is_none());
        }
    });
    dep.sim.run();
}

/// S3 Store semantics: PUT durable on archive; last racing PUT prevails.
#[test]
fn s3_store_put_semantics() {
    let dep = deploy(Testbed::Gcp, SystemKind::Lustre, 1, 2, RedundancyOpt::None);
    let server = dep.cluster.storage_nodes().next().unwrap().clone();
    let cnode = dep.client_nodes()[0].clone();
    let s3 = Rc::new(fdbr::s3::MemS3::new(&dep.sim, &server, &cnode));
    let mut fdb = FdbBuilder::new(&dep.sim)
        .backend(BackendConfig::S3 {
            s3: s3.clone(),
            client_tag: "proc0".to_string(),
            multipart: false,
        })
        .build()
        .unwrap();
    dep.sim.spawn(async move {
        let id = id_for(0, 1, 0);
        fdb.archive(&id, b"first").await.unwrap();
        // visible with NO flush (PutObject blocks until durable)
        let h = fdb.retrieve(&id).await.unwrap().unwrap();
        assert_eq!(fdb.read(&h).await.unwrap().to_vec(), b"first");
        fdb.archive(&id, b"second").await.unwrap();
        let h = fdb.retrieve(&id).await.unwrap().unwrap();
        assert_eq!(fdb.read(&h).await.unwrap().to_vec(), b"second");
    });
    dep.sim.run();
}
