//! Operational-workflow integration: the full NWP I/O pattern over each
//! backend, write+read contention effects, and the Lustre DLM behaviour
//! the thesis' operational analysis predicts (Fig 2.11 vs Fig 3.3).

use std::rc::Rc;

use fdbr::bench::scenario::{deploy, RedundancyOpt, SystemKind, SystemUnderTest};
use fdbr::hw::profiles::Testbed;
use fdbr::sim::time::SimTime;
use fdbr::workflow::driver::{run, OperationalConfig};
use fdbr::workflow::NullCompute;

#[test]
fn full_cycle_every_backend_verified() {
    for kind in [SystemKind::Lustre, SystemKind::Daos, SystemKind::Ceph] {
        let dep = deploy(Testbed::Gcp, kind, 2, 4, RedundancyOpt::None);
        let cfg = OperationalConfig {
            members: 2,
            procs_per_member: 4,
            steps: 5,
            fields_per_proc_step: 6,
            grid: 64,
            real_compute: false,
        };
        let report = run(&dep, cfg, Rc::new(NullCompute));
        assert_eq!(report.fields_read, report.fields_written, "{kind:?}");
        assert_eq!(report.fields_written, 2 * 4 * 5 * 6);
        assert!(report.makespan > SimTime::ZERO);
    }
}

#[test]
fn lustre_workflow_triggers_dlm_revocations() {
    // PGEN reads data files the I/O servers keep appending to — the
    // write+read contention the thesis identifies as Lustre's weak spot.
    let dep = deploy(
        Testbed::NextGenIo,
        SystemKind::Lustre,
        2,
        4,
        RedundancyOpt::None,
    );
    let cfg = OperationalConfig {
        members: 2,
        procs_per_member: 4,
        steps: 6,
        fields_per_proc_step: 8,
        grid: 64,
        real_compute: false,
    };
    let report = run(&dep, cfg, Rc::new(NullCompute));
    let SystemUnderTest::Lustre(fs) = &dep.system else {
        unreachable!()
    };
    let stats = fs.dlm_stats();
    assert!(
        stats.pw_revocations > 0,
        "PGEN reads during writing must revoke writer PW locks: {stats:?}"
    );
    assert!(
        report.trace.total(fdbr::sim::trace::OpClass::Lock) > SimTime::ZERO,
        "lock time must appear in the Lustre workflow profile"
    );
}

#[test]
fn daos_workflow_has_no_lock_time() {
    let dep = deploy(
        Testbed::NextGenIo,
        SystemKind::Daos,
        2,
        4,
        RedundancyOpt::None,
    );
    let cfg = OperationalConfig::default();
    let report = run(&dep, cfg, Rc::new(NullCompute));
    assert_eq!(
        report.trace.total(fdbr::sim::trace::OpClass::Lock),
        SimTime::ZERO,
        "MVCC: no client lock traffic on DAOS (thesis §2.3)"
    );
}

#[test]
fn daos_workflow_makespan_beats_lustre_under_heavy_contention() {
    // The operational pattern (not plain hammer) is where the thesis
    // expects object storage to pay off: heavy simultaneous write+read.
    let run_kind = |kind| {
        let dep = deploy(Testbed::NextGenIo, kind, 2, 4, RedundancyOpt::None);
        let cfg = OperationalConfig {
            members: 2,
            procs_per_member: 8,
            steps: 6,
            fields_per_proc_step: 16,
            grid: 128, // 64 KiB fields
            real_compute: false,
        };
        run(&dep, cfg, Rc::new(NullCompute)).makespan
    };
    let lustre = run_kind(SystemKind::Lustre);
    let daos = run_kind(SystemKind::Daos);
    assert!(
        daos < lustre,
        "operational makespan: DAOS {daos} should beat Lustre {lustre}"
    );
}

#[test]
fn larger_ensembles_scale_makespan_sublinearly() {
    // sanity on the DES: doubling members less than doubles makespan
    // (parallel writers share the same storage but overlap)
    let run_members = |members| {
        let dep = deploy(Testbed::Gcp, SystemKind::Daos, 2, 4, RedundancyOpt::None);
        let cfg = OperationalConfig {
            members,
            procs_per_member: 2,
            steps: 3,
            fields_per_proc_step: 6,
            grid: 64,
            real_compute: false,
        };
        run(&dep, cfg, Rc::new(NullCompute)).makespan
    };
    let m1 = run_members(1);
    let m4 = run_members(4);
    assert!(
        m4.as_nanos() < 4 * m1.as_nanos(),
        "4 members {m4} should be < 4× of 1 member {m1}"
    );
}
