//! Property-based tests (custom harness, see util::prop): randomized
//! invariants over the FDB's core data structures and the DES engine.

use fdbr::fdb::datahandle::DataHandle;
use fdbr::fdb::key::Key;
use fdbr::fdb::location::FieldLocation;
use fdbr::fdb::posix::index::{self, IndexEntry};
use fdbr::fdb::posix::toc::{Axes, IndexRef, TocRecord};
use fdbr::fdb::request::Request;
use fdbr::util::content::{Bytes, Content};
use fdbr::util::prop::check_no_shrink;
use fdbr::util::rng::Rng;

fn rand_token(rng: &mut Rng) -> String {
    let n = rng.range(1, 8);
    (0..n)
        .map(|_| (b'a' + rng.below(26) as u8) as char)
        .collect()
}

fn rand_key(rng: &mut Rng, ndims: usize) -> Key {
    let mut k = Key::new();
    for d in 0..ndims {
        k.set(&format!("d{d}"), rand_token(rng));
    }
    k
}

#[test]
fn prop_key_canonical_roundtrip() {
    check_no_shrink(
        11,
        500,
        |rng| {
            let n = rng.index(6) + 1;
            rand_key(rng, n)
        },
        |k| Key::parse(&k.canonical()).map(|p| p == *k).unwrap_or(false),
    );
}

#[test]
fn prop_request_expansion_count() {
    check_no_shrink(
        13,
        300,
        |rng| {
            let dims = rng.index(3) + 1;
            let mut req = Request::default();
            let mut expected = 1usize;
            for d in 0..dims {
                let nvals = rng.index(4) + 1;
                expected *= nvals;
                let vals: Vec<String> = (0..nvals).map(|i| format!("v{i}")).collect();
                req.dims.insert(format!("d{d}"), vals);
            }
            (req, expected)
        },
        |(req, expected)| {
            let keys = req.expand();
            keys.len() == *expected
                && keys.iter().all(|k| req.matches(k))
        },
    );
}

#[test]
fn prop_index_serialization_complete_and_ordered() {
    check_no_shrink(
        17,
        100,
        |rng| {
            let n = rng.index(500);
            let mut entries: Vec<IndexEntry> = (0..n)
                .map(|i| IndexEntry {
                    elem: format!("k{}={},n={i}", rng.index(5), rand_token(rng)),
                    uri_id: rng.below(4) as u32,
                    offset: rng.below(1 << 40),
                    length: rng.below(1 << 24),
                })
                .collect();
            entries.sort_by(|a, b| a.elem.cmp(&b.elem));
            entries.dedup_by(|a, b| a.elem == b.elem);
            entries
        },
        |entries| {
            let blob = index::serialize(entries);
            let Some((hl, count)) = index::parse_prelude(&blob[..12]) else {
                return false;
            };
            if count as usize != entries.len() {
                return false;
            }
            let Some(header) = index::parse_header(&blob[12..12 + hl as usize], count)
            else {
                return false;
            };
            let mut all = Vec::new();
            for p in &header.pages {
                match index::parse_page(&blob[p.off as usize..(p.off + p.len) as usize]) {
                    Some(es) => all.extend(es),
                    None => return false,
                }
            }
            // complete, ordered, and every entry findable via the page dir
            all == *entries
                && entries.iter().all(|e| {
                    index::page_for(&header, &e.elem)
                        .map(|p| {
                            index::parse_page(
                                &blob[p.off as usize..(p.off + p.len) as usize],
                            )
                            .map(|es| es.iter().any(|x| x == e))
                            .unwrap_or(false)
                        })
                        .unwrap_or(false)
                })
        },
    );
}

#[test]
fn prop_toc_stream_roundtrip_with_torn_tail() {
    check_no_shrink(
        19,
        200,
        |rng| {
            let n = rng.index(20);
            let records: Vec<TocRecord> = (0..n)
                .map(|_| match rng.index(4) {
                    0 => TocRecord::Init {
                        dataset: rand_token(rng),
                    },
                    1 => TocRecord::SubToc {
                        path: format!("/fdb/{}", rand_token(rng)),
                    },
                    2 => {
                        let mut axes = Axes::new();
                        axes.insert_key(&rand_key(rng, 2));
                        TocRecord::Index(IndexRef {
                            colloc: rand_key(rng, 2).canonical(),
                            index_path: format!("/fdb/{}.index", rand_token(rng)),
                            offset: rng.below(1 << 30),
                            length: rng.below(1 << 20),
                            axes,
                            uris: (0..rng.index(3))
                                .map(|_| format!("posix:///{}", rand_token(rng)))
                                .collect(),
                        })
                    }
                    _ => TocRecord::Mask {
                        path: format!("/fdb/{}", rand_token(rng)),
                    },
                })
                .collect();
            let torn = rng.index(3) == 0;
            (records, torn)
        },
        |(records, torn)| {
            let mut bytes = Vec::new();
            for r in records {
                bytes.extend(r.encode());
            }
            if *torn && !bytes.is_empty() {
                bytes.pop(); // tear the final record
            }
            let parsed = TocRecord::parse_stream(&bytes);
            if *torn && !records.is_empty() {
                parsed.len() == records.len() - 1
                    && parsed[..] == records[..records.len() - 1]
            } else {
                parsed == *records
            }
        },
    );
}

#[test]
fn prop_content_matches_reference_model() {
    // random interleaved writes/appends vs a plain Vec<u8> model
    check_no_shrink(
        23,
        150,
        |rng| {
            let nops = rng.index(30) + 1;
            let ops: Vec<(u64, Vec<u8>)> = (0..nops)
                .map(|_| {
                    let off = rng.below(2000);
                    let len = rng.index(200) + 1;
                    let mut data = vec![0u8; len];
                    rng.fill_bytes(&mut data);
                    (off, data)
                })
                .collect();
            ops
        },
        |ops| {
            let mut content = Content::new();
            let mut model: Vec<u8> = Vec::new();
            for (off, data) in ops {
                content.write(*off, Bytes::real(data.clone()));
                let end = *off as usize + data.len();
                if model.len() < end {
                    model.resize(end, 0);
                }
                model[*off as usize..end].copy_from_slice(data);
            }
            content.len() == model.len() as u64 && content.to_vec() == model
        },
    );
}

#[test]
fn prop_bytes_slice_equals_materialized_slice() {
    check_no_shrink(
        29,
        200,
        |rng| {
            let mut b = Bytes::new();
            for _ in 0..rng.index(6) + 1 {
                if rng.index(2) == 0 {
                    let mut v = vec![0u8; rng.index(100) + 1];
                    rng.fill_bytes(&mut v);
                    b.append(Bytes::real(v));
                } else {
                    b.append(Bytes::virt(rng.below(200) + 1, rng.next_u64()));
                }
            }
            let off = rng.below(b.len());
            let len = rng.below(b.len() - off + 1);
            (b, off, len)
        },
        |(b, off, len)| {
            let whole = b.to_vec();
            let slice = b.slice(*off, *len);
            slice.to_vec() == whole[*off as usize..(*off + *len) as usize]
        },
    );
}

#[test]
fn prop_datahandle_merge_preserves_bytes_and_never_increases_ops() {
    check_no_shrink(
        31,
        200,
        |rng| {
            let nfiles = rng.index(3) + 1;
            let n = rng.index(12) + 1;
            let handles: Vec<DataHandle> = (0..n)
                .map(|_| {
                    DataHandle::from_location(&FieldLocation::PosixFile {
                        path: format!("/f{}", rng.index(nfiles)),
                        offset: rng.below(10_000),
                        length: rng.below(500) + 1,
                        checksum: None,
                    })
                })
                .collect();
            handles
        },
        |handles| {
            let total_ops: usize = handles.iter().map(|h| h.io_ops()).sum();
            let merged = DataHandle::merge_all(handles.clone());
            let merged_ops: usize = merged.iter().map(|h| h.io_ops()).sum();
            // ops never increase; total coverage never shrinks (ranges
            // may coalesce overlapping spans, so length can only grow
            // equal-or-less... coverage in ops is the invariant here)
            merged_ops <= total_ops && !merged.is_empty()
        },
    );
}

#[test]
fn prop_sim_determinism() {
    // identical workloads produce identical virtual end times
    check_no_shrink(
        37,
        30,
        |rng| (rng.next_u64(), rng.index(20) + 1),
        |(seed, tasks)| {
            let run_once = || {
                let sim = fdbr::sim::exec::Sim::new();
                let res = fdbr::sim::resource::Resource::new("r", 2);
                let mut rng = Rng::new(*seed);
                for _ in 0..*tasks {
                    let s = sim.clone();
                    let r = res.clone();
                    let d = rng.below(1000) + 1;
                    sim.spawn(async move {
                        r.serve(&s, fdbr::sim::time::SimTime::nanos(d)).await;
                        s.sleep(fdbr::sim::time::SimTime::nanos(d / 2)).await;
                        r.serve(&s, fdbr::sim::time::SimTime::nanos(d * 2)).await;
                    });
                }
                sim.run()
            };
            run_once() == run_once()
        },
    );
}
