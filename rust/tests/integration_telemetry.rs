//! Telemetry subsystem integration tests.
//!
//! The contract of the observability layer: attaching a
//! `MetricsRegistry` must be **observationally free** — byte- and
//! order-identical results and an identical virtual clock over a nested
//! wrapper stack — while the registry's per-class service histograms
//! agree *exactly* (count and summed nanoseconds) with the `Trace` the
//! benchmarks have always reported. Plus the slow-op log regression
//! (an injected `slow:read` fault must surface ops above
//! `IoProfile::slow_op_us`) and the wall-clock overhead bound on a
//! Null-backend hammer run.

use std::cell::RefCell;
use std::rc::Rc;

use fdbr::bench::hammer::{self, HammerConfig};
use fdbr::bench::scenario::{deploy, RedundancyOpt, SystemKind};
use fdbr::fdb::{BackendConfig, FaultPlan, Fdb, FdbBuilder, IoProfile, Key, MetricsRegistry};
use fdbr::hw::profiles::Testbed;
use fdbr::sim::exec::Sim;
use fdbr::sim::trace::OpClass;
use fdbr::util::content::Bytes;
use fdbr::util::rng::Rng;

fn field_id(step: u32, param: u32) -> Key {
    fdbr::bench::hammer::field_id(0, step, param, 0)
}

fn payload(step: u32, param: u32, size: u64) -> Bytes {
    Bytes::virt(size, (u64::from(step) << 32) | (u64::from(param) << 8) | (size & 0xff))
}

/// FNV-1a over materialized bytes (payloads here are tiny).
fn digest(bytes: &[u8]) -> u64 {
    let mut h: u64 = 0xcbf29ce484222325;
    for &b in bytes {
        h ^= u64::from(b);
        h = h.wrapping_mul(0x100000001b3);
    }
    h
}

/// Everything observable after one archive→retrieve cycle, in order,
/// plus the virtual clock at the end of the run.
#[derive(Clone, Debug, Default, PartialEq, Eq)]
struct Fingerprint {
    fetched: Vec<(String, u64, u64)>,
    end_ns: u64,
}

/// One randomized workload over a `sharded(replicated(lustre))` nested
/// stack built straight from `BackendConfig`, with or without a
/// registry attached. Returns the ordered fingerprint and the registry
/// (so the caller can check the instrumented run actually recorded).
fn nested_stack_run(wl: &[(u32, u32, u64)], instrumented: bool) -> (Fingerprint, MetricsRegistry) {
    let dep = deploy(Testbed::Gcp, SystemKind::Lustre, 2, 2, RedundancyOpt::None);
    let nested = BackendConfig::Sharded {
        inner: Box::new(BackendConfig::Replicated {
            inner: Box::new(dep.backend_config()),
            copies: 2,
        }),
        shards: 2,
    };
    let reg = MetricsRegistry::new();
    let io = IoProfile::depth(4).with_preload_indexes(true).with_coalesce_gap(1 << 16);
    let nodes = dep.client_nodes();
    let build = |node, sim: &Sim| -> Fdb {
        let mut b = FdbBuilder::new(sim).node(node).backend(nested.clone()).io(io);
        if instrumented {
            b = b.metrics(&reg);
        }
        b.build().expect("nested stack builds")
    };
    let mut w = build(&nodes[0], &dep.sim);
    let mut r = build(&nodes[1], &dep.sim);
    let out = Rc::new(RefCell::new(Fingerprint::default()));
    {
        let out = out.clone();
        let wl = wl.to_vec();
        let sim = dep.sim.clone();
        dep.sim.spawn(async move {
            let mut batch: Vec<(Key, Bytes)> = Vec::new();
            let mut ids: Vec<Key> = Vec::new();
            let mut seen = std::collections::BTreeSet::new();
            for &(step, param, size) in &wl {
                let id = field_id(step, param);
                batch.push((id.clone(), payload(step, param, size)));
                if seen.insert(id.canonical()) {
                    ids.push(id);
                }
            }
            w.archive_many(batch).await.unwrap();
            w.flush().await.unwrap();
            w.close().await.expect("close");
            let fetched = r.retrieve_many(&ids).await.unwrap();
            let mut fp = Fingerprint::default();
            for (id, bytes) in &fetched {
                let v = bytes.to_vec();
                fp.fetched.push((id.canonical(), v.len() as u64, digest(&v)));
            }
            fp.end_ns = sim.now().as_nanos();
            *out.borrow_mut() = fp;
        });
        dep.sim.run();
    }
    let fp = out.borrow().clone();
    (fp, reg)
}

#[test]
fn metrics_are_observationally_free_over_the_nested_stack() {
    // the equivalence property: metrics on vs. off is byte- and
    // order-identical — same fetched bytes, same order, same virtual
    // end time — over a sharded(replicated(posix)) stack, across
    // randomized workloads
    let mut rng = Rng::new(0x0B5E);
    for _ in 0..3 {
        let n = 6 + rng.below(10) as usize;
        let wl: Vec<(u32, u32, u64)> = (0..n)
            .map(|_| {
                (
                    1 + rng.below(5) as u32,
                    rng.below(4) as u32,
                    64 + rng.below(6000),
                )
            })
            .collect();
        let (plain, plain_reg) = nested_stack_run(&wl, false);
        let (observed, reg) = nested_stack_run(&wl, true);
        assert!(!plain.fetched.is_empty(), "workload must fetch something");
        assert_eq!(plain, observed, "telemetry must not perturb results or timing");
        // not vacuous: the instrumented run really recorded, at every
        // layer of the stack, and the plain run really did not
        let reads = reg.hist("engine.service.data-read").map_or(0, |s| s.count());
        assert!(reads > 0, "instrumented run records engine service times");
        assert!(
            reg.hist_names().iter().any(|n| n.starts_with("store.r0.")),
            "per-replica leaf metrics present: {:?}",
            reg.hist_names()
        );
        assert!(
            reg.counter_value("cat.s0.posix.archive.ok") + reg.counter_value("cat.s1.posix.archive.ok")
                > 0,
            "per-shard catalogue counts present"
        );
        assert!(plain_reg.hist_names().is_empty(), "no registry attached, no metrics");
    }
}

#[test]
fn telemetry_overhead_is_bounded_on_null_hammer() {
    // the overhead bound: registry + ring buffer must add < 5% wall
    // clock to a Null-backend hammer run. Interleave 5 (off, on) pairs
    // and compare the minima — the minimum of a deterministic
    // single-threaded run is stable; a small absolute slack absorbs
    // timer granularity on a fast run.
    let cfg = HammerConfig {
        procs_per_node: 8,
        nsteps: 12,
        nparams: 4,
        nlevels: 2,
        field_size: 1 << 16,
        check: false,
        contention: false,
        faults_ok: false,
    };
    let run = |instrumented: bool| -> std::time::Duration {
        let mut dep = deploy(Testbed::Gcp, SystemKind::Null, 2, 2, RedundancyOpt::None);
        let reg = MetricsRegistry::new();
        if instrumented {
            dep = dep.with_metrics(&reg);
        }
        let t0 = std::time::Instant::now();
        let _ = hammer::run(&dep, cfg);
        t0.elapsed()
    };
    let mut best_off = std::time::Duration::MAX;
    let mut best_on = std::time::Duration::MAX;
    for _ in 0..5 {
        best_off = best_off.min(run(false));
        best_on = best_on.min(run(true));
    }
    let bound = best_off.mul_f64(1.05) + std::time::Duration::from_millis(2);
    assert!(
        best_on <= bound,
        "telemetry overhead above 5%: off={best_off:?} on={best_on:?}"
    );
}

#[test]
fn slow_op_log_records_injected_slow_reads() {
    // the slow-op regression: an injected `slow:read` fault (delays,
    // does not error) must surface in the registry's slow-op log once
    // `IoProfile::slow_op_us` is set, with class/backend/duration
    let plan = FaultPlan::parse("seed=7,slow:read:20000").expect("fault spec");
    let reg = MetricsRegistry::new();
    let dep = deploy(Testbed::Gcp, SystemKind::Lustre, 2, 2, RedundancyOpt::None)
        .with_io(IoProfile::default().with_slow_op_us(2000))
        .with_fault(plan)
        .with_metrics(&reg);
    let nodes = dep.client_nodes();
    let ids: Vec<Key> = (0..8).map(|i| field_id(1 + i, 0)).collect();
    let mut w = dep.fdb(&nodes[0]);
    let mut r = dep.fdb(&nodes[1]);
    {
        let ids = ids.clone();
        dep.sim.spawn(async move {
            let batch: Vec<(Key, Bytes)> = ids
                .iter()
                .enumerate()
                .map(|(i, id)| (id.clone(), payload(1 + i as u32, 0, 4096)))
                .collect();
            w.archive_many(batch).await.unwrap();
            w.flush().await.unwrap();
            w.close().await.expect("close");
            let fetched = r.retrieve_many(&ids).await.unwrap();
            assert_eq!(fetched.len(), ids.len());
        });
        dep.sim.run();
    }
    let slow = reg.slow_ops();
    assert!(!slow.is_empty(), "20ms injected delay must cross the 2ms threshold");
    assert!(
        slow.iter().all(|op| op.duration.as_nanos() >= 2_000_000),
        "every logged op is at or above the threshold"
    );
    assert!(
        slow.iter().any(|op| op.class == OpClass::DataRead && !op.backend.is_empty()),
        "the injected slow reads are logged with class and backend: {slow:?}"
    );

    // and with the default profile (slow_op_us = 0) the log stays off
    // even with a registry attached and the same fault injected
    let plan = FaultPlan::parse("seed=7,slow:read:20000").expect("fault spec");
    let reg = MetricsRegistry::new();
    let dep = deploy(Testbed::Gcp, SystemKind::Lustre, 2, 2, RedundancyOpt::None)
        .with_fault(plan)
        .with_metrics(&reg);
    let nodes = dep.client_nodes();
    let ids2: Vec<Key> = (0..4).map(|i| field_id(1 + i, 0)).collect();
    let mut w = dep.fdb(&nodes[0]);
    let mut r = dep.fdb(&nodes[1]);
    {
        let ids2 = ids2.clone();
        dep.sim.spawn(async move {
            let batch: Vec<(Key, Bytes)> = ids2
                .iter()
                .enumerate()
                .map(|(i, id)| (id.clone(), payload(1 + i as u32, 0, 4096)))
                .collect();
            w.archive_many(batch).await.unwrap();
            w.flush().await.unwrap();
            w.close().await.expect("close");
            let _ = r.retrieve_many(&ids2).await.unwrap();
        });
        dep.sim.run();
    }
    assert!(reg.slow_ops().is_empty(), "slow-op log defaults to off");
}

#[test]
fn registry_histograms_agree_exactly_with_the_trace() {
    // the consistency bar: for every op class, the registry's
    // `engine.service.<class>` histogram must hold exactly the same
    // sample count and summed (lock-subtracted) nanoseconds as the
    // `Trace` the same run reported — the two views never drift
    let reg = MetricsRegistry::new();
    let dep = deploy(Testbed::Gcp, SystemKind::Lustre, 2, 2, RedundancyOpt::None)
        .with_io(IoProfile::depth(4).with_preload_indexes(true))
        .with_metrics(&reg);
    let cfg = HammerConfig {
        procs_per_node: 4,
        nsteps: 4,
        nparams: 2,
        nlevels: 2,
        field_size: 1 << 16,
        check: true,
        contention: false,
        faults_ok: false,
    };
    let (_bw, trace) = hammer::run(&dep, cfg);
    let mut matched = 0;
    for class in OpClass::ALL {
        let name = format!("engine.service.{}", class.label());
        let (count, sum) = reg.hist(&name).map_or((0, 0), |s| (s.count(), s.sum()));
        assert_eq!(count, trace.count(class), "{name}: sample count drifted from Trace");
        assert_eq!(
            sum,
            trace.total(class).as_nanos(),
            "{name}: summed nanoseconds drifted from Trace"
        );
        if count > 0 {
            matched += 1;
        }
    }
    assert!(matched >= 3, "hammer exercises several op classes, matched {matched}");
}
