//! I/O-depth engine equivalence and bound tests.
//!
//! Property: for any `io_depth >= 1`, the batched `archive_many` /
//! `retrieve_many` paths return **byte- and order-identical** results to
//! `io_depth = 1` — over the Null pair, bare POSIX/Lustre, and wrapped
//! stacks (tiered / replicated / sharded) — only virtual time may
//! differ. Plus: the engine's semaphore bound (in-flight sessions never
//! exceed the configured depth), `IoProfile` validation, and the
//! catalogue-side mkdir-panic regression (fallible `Catalogue::archive`).

use std::cell::RefCell;
use std::rc::Rc;

use fdbr::bench::scenario::{deploy, RedundancyOpt, SystemKind, SystemUnderTest, WrapperOpt};
use fdbr::fdb::{
    BackendConfig, Catalogue, Fdb, FdbBuilder, FdbError, FieldLocation, IoProfile, Key,
    Request,
};
use fdbr::hw::profiles::Testbed;
use fdbr::lustre::StripeSpec;
use fdbr::sim::exec::Sim;
use fdbr::util::content::Bytes;
use fdbr::util::prop;
use fdbr::util::rng::Rng;

/// One randomized batched workload: fields addressed by (step, param)
/// with per-field payload sizes. Repeated (step, param) pairs re-archive
/// the field within the same batch (input-order-last must win).
#[derive(Clone, Debug)]
struct Workload {
    fields: Vec<(u32, u32, u64)>,
}

fn gen_workload(rng: &mut Rng) -> Workload {
    let n = 1 + rng.below(14) as usize;
    let fields = (0..n)
        .map(|_| {
            (
                1 + rng.below(4) as u32,
                rng.below(3) as u32,
                64 + rng.below(4096),
            )
        })
        .collect();
    Workload { fields }
}

fn field_id(step: u32, param: u32) -> Key {
    fdbr::bench::hammer::field_id(0, step, param, 0)
}

fn payload(step: u32, param: u32, size: u64) -> Bytes {
    Bytes::virt(size, (u64::from(step) << 32) | (u64::from(param) << 8) | (size & 0xff))
}

/// FNV-1a over materialized bytes (payloads here are tiny).
fn digest(bytes: &[u8]) -> u64 {
    let mut h: u64 = 0xcbf29ce484222325;
    for &b in bytes {
        h ^= u64::from(b);
        h = h.wrapping_mul(0x100000001b3);
    }
    h
}

/// Everything observable after the batched workload, **in order**:
/// `retrieve_many` results as an ordered (identifier, len, digest) list
/// plus the sorted listing of the dataset.
#[derive(Clone, Debug, Default, PartialEq, Eq)]
struct Fingerprint {
    fetched: Vec<(String, u64, u64)>,
    listed: Vec<String>,
    inflight_peak_ok: bool,
}

/// Archive the whole workload as ONE `archive_many` batch through `w`
/// (flush + close), then fetch every unique identifier in one
/// `retrieve_many` through `r` (or `w` itself for process-local
/// catalogues). Returns the ordered fingerprint.
fn run_batched(sim: &Sim, w: Fdb, r: Option<Fdb>, wl: &Workload) -> Fingerprint {
    let out = Rc::new(RefCell::new(Fingerprint::default()));
    let out2 = out.clone();
    let wl = wl.clone();
    let mut w = w;
    sim.spawn(async move {
        let mut batch: Vec<(Key, Bytes)> = Vec::new();
        let mut ids: Vec<Key> = Vec::new();
        let mut seen = std::collections::BTreeSet::new();
        for &(step, param, size) in &wl.fields {
            let id = field_id(step, param);
            batch.push((id.clone(), payload(step, param, size)));
            if seen.insert(id.canonical()) {
                ids.push(id);
            }
        }
        let depth = w.io_profile().depth;
        w.archive_many(batch).await.unwrap();
        w.flush().await.unwrap();
        w.close().await.expect("close");
        let w_peak_ok = w.io_inflight_peak() <= depth.max(1);
        let mut r = r.unwrap_or(w);
        let fetched = r.retrieve_many(&ids).await.unwrap();
        let mut fp = Fingerprint {
            inflight_peak_ok: w_peak_ok && r.io_inflight_peak() <= depth.max(1),
            ..Fingerprint::default()
        };
        for (id, bytes) in &fetched {
            let v = bytes.to_vec();
            fp.fetched.push((id.canonical(), v.len() as u64, digest(&v)));
        }
        let ds = ids[0].project(&r.schema.dataset.clone()).unwrap();
        let mut listed: Vec<String> = r
            .list(&ds, &Request::parse("").unwrap())
            .await
            .iter()
            .map(|(k, _)| k.canonical())
            .collect();
        listed.sort();
        fp.listed = listed;
        *out2.borrow_mut() = fp;
    });
    sim.run();
    let fp = out.borrow().clone();
    fp
}

/// Fingerprint the Null pair at a given depth on a fresh Sim.
fn null_fingerprint(depth: usize, wl: &Workload) -> Fingerprint {
    let sim = Sim::new();
    let w = FdbBuilder::new(&sim)
        .backend(BackendConfig::Null)
        .io_depth(depth)
        .build()
        .unwrap();
    run_batched(&sim, w, None, wl)
}

#[test]
fn any_depth_equals_depth_one_over_null() {
    prop::check_no_shrink(0xD0E, 8, gen_workload, |wl| {
        let base = null_fingerprint(1, wl);
        assert!(!base.fetched.is_empty(), "workload must fetch fields");
        [2usize, 3, 8, 16]
            .into_iter()
            .all(|d| null_fingerprint(d, wl) == base)
    });
}

#[test]
fn any_depth_equals_depth_one_over_posix_and_wrapped_stacks() {
    // cross-process: writer on node 0, reader on node 1, the full
    // archive_many -> flush -> close -> retrieve_many cycle
    let mut rng = Rng::new(0x10D3);
    let cases: Vec<Workload> = (0..3).map(|_| gen_workload(&mut rng)).collect();
    let stacks = [
        WrapperOpt::Bare,
        WrapperOpt::Tiered,
        WrapperOpt::Replicated(2),
        WrapperOpt::Sharded(3),
    ];
    for wrapper in stacks {
        let fingerprints = |depth: usize| -> Vec<Fingerprint> {
            let dep = deploy(Testbed::Gcp, SystemKind::Lustre, 2, 2, RedundancyOpt::None)
                .with_wrapper(wrapper)
                .with_io_depth(depth);
            let nodes = dep.client_nodes();
            cases
                .iter()
                .map(|wl| {
                    let w = dep.fdb(&nodes[0]);
                    let r = dep.fdb(&nodes[1]);
                    run_batched(&dep.sim, w, Some(r), wl)
                })
                .collect()
        };
        let base = fingerprints(1);
        assert!(base.iter().all(|fp| !fp.fetched.is_empty()));
        for depth in [2usize, 4, 8] {
            assert_eq!(
                fingerprints(depth),
                base,
                "{wrapper:?} at depth {depth} must be byte- and order-identical to depth 1"
            );
        }
    }
}

#[test]
fn direct_retrieve_fanout_equals_serial_on_hashed_daos() {
    // the hash-OID fast path has its own fan-out (lookup+read per
    // session); it must match the serial direct path exactly.
    // Identifiers are deduplicated (input-order-last wins) before the
    // batch: hash-OID placement maps a repeated identifier to the SAME
    // array, and concurrent rewrites of one object are last-writer-wins
    // in any real object store — not an ordering the engine defines.
    let mut rng = Rng::new(0xDA05);
    let cases: Vec<Workload> = (0..3)
        .map(|_| {
            let wl = gen_workload(&mut rng);
            let mut last: std::collections::BTreeMap<(u32, u32), (u32, u32, u64)> =
                std::collections::BTreeMap::new();
            for f in &wl.fields {
                last.insert((f.0, f.1), *f);
            }
            Workload {
                fields: last.into_values().collect(),
            }
        })
        .collect();
    let fingerprints = |depth: usize| -> Vec<Fingerprint> {
        let dep = deploy(Testbed::Gcp, SystemKind::Daos, 2, 2, RedundancyOpt::None);
        let SystemUnderTest::Daos(d) = &dep.system else {
            unreachable!()
        };
        let nodes = dep.client_nodes();
        let mk = |node| {
            FdbBuilder::new(&dep.sim)
                .node(node)
                .backend(BackendConfig::Daos {
                    daos: d.clone(),
                    pool: "fdb".to_string(),
                    hash_oids: true,
                })
                .io_depth(depth)
                .build()
                .unwrap()
        };
        cases
            .iter()
            .map(|wl| {
                let w = mk(&nodes[0]);
                let r = mk(&nodes[1]);
                run_batched(&dep.sim, w, Some(r), wl)
            })
            .collect()
    };
    let base = fingerprints(1);
    // listing goes through the catalogue; the hashed store still indexes
    // it, so listings stay comparable too
    assert!(base.iter().all(|fp| !fp.fetched.is_empty()));
    for depth in [3usize, 8] {
        assert_eq!(fingerprints(depth), base, "hashed DAOS at depth {depth}");
    }
}

#[test]
fn inflight_sessions_never_exceed_configured_depth() {
    // instrumented-counter bound: the semaphore admits at most `depth`
    // concurrent session ops, and on a real (latency-bearing) backend
    // the engine genuinely reaches more than one in flight
    let dep = deploy(Testbed::Gcp, SystemKind::Lustre, 2, 2, RedundancyOpt::None)
        .with_io(IoProfile::depth(4).with_preload_indexes(true));
    let nodes = dep.client_nodes();
    let mut w = dep.fdb(&nodes[0]);
    let mut r = dep.fdb(&nodes[1]);
    let peaks = Rc::new(RefCell::new((0usize, 0usize, 0usize)));
    let peaks2 = peaks.clone();
    dep.sim.spawn(async move {
        let batch: Vec<(Key, Bytes)> = (0..32u32)
            .map(|i| {
                let id = field_id(1 + i / 8, i % 8);
                (id, Bytes::virt(32 << 10, u64::from(i)))
            })
            .collect();
        let ids: Vec<Key> = batch.iter().map(|(id, _)| id.clone()).collect();
        w.archive_many(batch).await.unwrap();
        w.flush().await.unwrap();
        w.close().await.expect("close");
        let fetched = r.retrieve_many(&ids).await.unwrap();
        assert_eq!(fetched.len(), ids.len());
        *peaks2.borrow_mut() = (w.io_inflight_peak(), r.io_inflight_peak(), r.io_sessions());
    });
    dep.sim.run();
    let (w_peak, r_peak, r_sessions) = *peaks.borrow();
    assert!(w_peak <= 4, "writer in-flight peak {w_peak} exceeds depth 4");
    assert!(r_peak <= 4, "reader in-flight peak {r_peak} exceeds depth 4");
    assert_eq!(r_sessions, 4, "reader should hold a full session pool");
    // the bound is tight in practice: concurrency actually happened
    assert!(w_peak >= 2, "writer never overlapped ops (peak {w_peak})");
    assert!(r_peak >= 2, "reader never overlapped ops (peak {r_peak})");
}

#[test]
fn io_profile_validation() {
    for depth in [0usize, 65] {
        let sim = Sim::new();
        let err = FdbBuilder::new(&sim)
            .backend(BackendConfig::Null)
            .io_depth(depth)
            .build()
            .unwrap_err();
        assert!(
            matches!(err, FdbError::InvalidConfig(_)),
            "depth {depth} must be rejected, got {err}"
        );
    }
    // depth 1 and 64 are the inclusive bounds
    for depth in [1usize, 64] {
        let sim = Sim::new();
        assert!(FdbBuilder::new(&sim)
            .backend(BackendConfig::Null)
            .io_depth(depth)
            .build()
            .is_ok());
    }
}

#[test]
fn posix_catalogue_mkdir_failure_is_typed_error() {
    // regression for the last archive-path panic (ROADMAP item): the
    // catalogue root colliding with a regular file must surface as
    // FdbError::Backend through the now-fallible Catalogue::archive
    let dep = deploy(Testbed::Gcp, SystemKind::Lustre, 2, 2, RedundancyOpt::None);
    let SystemUnderTest::Lustre(fs) = &dep.system else {
        unreachable!()
    };
    let node = dep.client_nodes()[0].clone();
    let mut saboteur = fs.client(&node);
    let mut cat: Box<dyn Catalogue> = Box::new(fdbr::fdb::posix::catalogue::PosixCatalogue::new(
        fs.client(&node),
        "/idxroot",
        fdbr::fdb::Schema::default_posix(),
    ));
    let outcome = Rc::new(RefCell::new(None));
    let outcome2 = outcome.clone();
    dep.sim.spawn(async move {
        // a regular file squats on the catalogue root
        saboteur
            .create("/idxroot", StripeSpec::default_layout())
            .await
            .unwrap();
        let id = field_id(1, 0);
        let ds = id.project(&fdbr::fdb::Schema::default_posix().dataset).unwrap();
        let loc = FieldLocation::Null { length: 7 };
        let r = cat.archive(&ds, &ds, &id, &id, &loc).await;
        *outcome2.borrow_mut() = Some(r);
    });
    dep.sim.run();
    let got = outcome.borrow_mut().take().expect("archive ran");
    match got {
        Err(FdbError::Backend { backend, detail }) => {
            assert_eq!(backend, "posix");
            assert!(detail.contains("mkdir"), "detail should name mkdir: {detail}");
        }
        other => panic!("expected typed posix backend error, got {other:?}"),
    }
}

#[test]
fn catalogue_error_propagates_through_fdb_archive() {
    // end-to-end ripple: a healthy store + a sabotaged catalogue root —
    // Fdb::archive must return the catalogue's typed error, and the
    // field stays invisible (un-indexed)
    let dep = deploy(Testbed::Gcp, SystemKind::Lustre, 2, 2, RedundancyOpt::None);
    let SystemUnderTest::Lustre(fs) = &dep.system else {
        unreachable!()
    };
    let node = dep.client_nodes()[0].clone();
    let mut saboteur = fs.client(&node);
    let schema = fdbr::fdb::Schema::default_posix();
    let store = Box::new(fdbr::fdb::posix::store::PosixStore::new(
        fs.client(&node),
        "/data",
    ));
    let catalogue = Box::new(fdbr::fdb::posix::catalogue::PosixCatalogue::new(
        fs.client(&node),
        "/idx",
        schema.clone(),
    ));
    let mut fdb = Fdb::new(&dep.sim, schema, store, catalogue);
    let outcome = Rc::new(RefCell::new(None));
    let outcome2 = outcome.clone();
    dep.sim.spawn(async move {
        saboteur
            .create("/idx", StripeSpec::default_layout())
            .await
            .unwrap();
        let id = field_id(1, 0);
        let r = fdb.archive(&id, b"payload".as_slice()).await;
        *outcome2.borrow_mut() = Some(r);
    });
    dep.sim.run();
    let got = outcome.borrow_mut().take().expect("archive ran");
    assert!(
        matches!(got, Err(FdbError::Backend { backend: "posix", .. })),
        "expected posix backend error, got {got:?}"
    );
}
