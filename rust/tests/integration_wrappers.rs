//! Wrapper-equivalence property tests: `TieredStore`, `ReplicatedStore`
//! and `ShardedCatalogue` (in any recursive composition) must be
//! observably identical to the bare inner backend — byte-identical
//! retrieves, identical listings and axes — on the archive/flush/
//! retrieve/list workloads of `integration_consistency.rs`. Plus
//! regression tests that the former backend panic sites now surface as
//! typed `FdbError`s.

use std::cell::RefCell;
use std::rc::Rc;

use fdbr::bench::scenario::{deploy, RedundancyOpt, SystemKind, SystemUnderTest, WrapperOpt};
use fdbr::fdb::{BackendConfig, Fdb, FdbBuilder, FdbError, Key, Request};
use fdbr::hw::profiles::Testbed;
use fdbr::sim::exec::Sim;
use fdbr::util::content::Bytes;
use fdbr::util::prop;
use fdbr::util::rng::Rng;

/// One randomized workload: fields addressed by (step, param) with
/// per-field payload sizes. Repeats re-archive (replace) the field.
#[derive(Clone, Debug)]
struct Workload {
    fields: Vec<(u32, u32, u64)>,
}

fn gen_workload(rng: &mut Rng) -> Workload {
    let n = 1 + rng.below(12) as usize;
    let fields = (0..n)
        .map(|_| {
            (
                1 + rng.below(4) as u32,
                rng.below(3) as u32,
                64 + rng.below(4096),
            )
        })
        .collect();
    Workload { fields }
}

fn field_id(step: u32, param: u32) -> Key {
    fdbr::bench::hammer::field_id(0, step, param, 0)
}

fn payload(step: u32, param: u32, size: u64) -> Bytes {
    Bytes::virt(size, (u64::from(step) << 32) | u64::from(param))
}

/// FNV-1a over materialized bytes (payloads here are tiny).
fn digest(bytes: &[u8]) -> u64 {
    let mut h: u64 = 0xcbf29ce484222325;
    for &b in bytes {
        h ^= u64::from(b);
        h = h.wrapping_mul(0x100000001b3);
    }
    h
}

/// Everything observable about a dataset after the workload: per-id
/// retrieve outcomes (byte digests), the sorted listing, and one axis.
#[derive(Clone, Debug, Default, PartialEq, Eq)]
struct Fingerprint {
    retrieved: Vec<(String, Option<(u64, u64)>)>,
    listed: Vec<String>,
    axis: Vec<String>,
}

/// Run the workload: archive everything through `w` (flush + close),
/// then observe through `r` (or through `w` itself when `r` is `None` —
/// process-local catalogues like the bare Null pair).
fn run_workload(sim: &Sim, w: Fdb, r: Option<Fdb>, wl: &Workload) -> Fingerprint {
    let out = Rc::new(RefCell::new(Fingerprint::default()));
    let out2 = out.clone();
    let wl = wl.clone();
    let mut w = w;
    sim.spawn(async move {
        let mut ids: Vec<Key> = Vec::new();
        for &(step, param, size) in &wl.fields {
            let id = field_id(step, param);
            w.archive(&id, payload(step, param, size)).await.unwrap();
            ids.push(id);
        }
        w.flush().await.unwrap();
        w.close().await.expect("close");
        let mut r = r.unwrap_or(w);
        let mut fp = Fingerprint::default();
        let mut seen = std::collections::BTreeSet::new();
        for id in &ids {
            if !seen.insert(id.canonical()) {
                continue;
            }
            let got = match r.retrieve(id).await.unwrap() {
                None => None,
                Some(h) => {
                    let bytes = r.read(&h).await.unwrap().to_vec();
                    Some((bytes.len() as u64, digest(&bytes)))
                }
            };
            fp.retrieved.push((id.canonical(), got));
        }
        let ds = ids[0].project(&r.schema.dataset.clone()).unwrap();
        let colloc = ids[0].project(&r.schema.collocation.clone()).unwrap();
        let mut listed: Vec<String> = r
            .list(&ds, &Request::parse("").unwrap())
            .await
            .iter()
            .map(|(k, _)| k.canonical())
            .collect();
        listed.sort();
        fp.listed = listed;
        fp.axis = r.axes(&ds, &colloc, "step").await;
        *out2.borrow_mut() = fp;
    });
    sim.run();
    let fp = out.borrow().clone();
    fp
}

/// Fingerprint a config on a fresh standalone Sim, same-process
/// writer/reader (Null-family backends need no cluster).
fn null_fingerprint(config: BackendConfig, wl: &Workload) -> Fingerprint {
    let sim = Sim::new();
    let w = FdbBuilder::new(&sim).backend(config).build().unwrap();
    run_workload(&sim, w, None, wl)
}

#[test]
fn wrappers_over_null_equivalent_to_bare() {
    // property: for random workloads, every wrapper composition over the
    // Null pair fingerprints identically to the bare Null pair
    prop::check_no_shrink(0xB0B, 10, gen_workload, |wl| {
        let base = null_fingerprint(BackendConfig::Null, wl);
        assert!(
            !base.listed.is_empty(),
            "workload must index at least one field"
        );
        let compositions: Vec<BackendConfig> = vec![
            BackendConfig::Tiered {
                front: Box::new(BackendConfig::Null),
                back: Box::new(BackendConfig::Null),
            },
            BackendConfig::Replicated {
                inner: Box::new(BackendConfig::Null),
                copies: 3,
            },
            BackendConfig::Sharded {
                inner: Box::new(BackendConfig::Null),
                shards: 3,
            },
            // recursive composition: sharded catalogue over a tiered
            // store whose back tier is replicated
            BackendConfig::Sharded {
                inner: Box::new(BackendConfig::Tiered {
                    front: Box::new(BackendConfig::Null),
                    back: Box::new(BackendConfig::Replicated {
                        inner: Box::new(BackendConfig::Null),
                        copies: 2,
                    }),
                }),
                shards: 2,
            },
        ];
        compositions
            .into_iter()
            .all(|c| null_fingerprint(c, wl) == base)
    });
}

#[test]
fn wrappers_over_posix_equivalent_to_bare() {
    // cross-process equivalence on a real (simulated) Lustre deployment:
    // writer on node 0, reader on node 1, random workloads
    let mut rng = Rng::new(0x5EED);
    let cases: Vec<Workload> = (0..4).map(|_| gen_workload(&mut rng)).collect();
    let fingerprints = |wrapper: WrapperOpt| -> Vec<Fingerprint> {
        let dep = deploy(Testbed::Gcp, SystemKind::Lustre, 2, 2, RedundancyOpt::None)
            .with_wrapper(wrapper);
        let nodes = dep.client_nodes();
        cases
            .iter()
            .map(|wl| {
                let w = dep.fdb(&nodes[0]);
                let r = dep.fdb(&nodes[1]);
                run_workload(&dep.sim, w, Some(r), wl)
            })
            .collect()
    };
    let base = fingerprints(WrapperOpt::Bare);
    assert!(base.iter().all(|fp| !fp.listed.is_empty()));
    for wrapper in [
        WrapperOpt::Tiered,
        WrapperOpt::Replicated(2),
        WrapperOpt::Sharded(3),
    ] {
        assert_eq!(
            fingerprints(wrapper),
            base,
            "{wrapper:?} must be observably identical to bare posix"
        );
    }
}

#[test]
fn recursive_posix_composition_equivalent_to_bare() {
    // sharded catalogue over a tiered store whose back tier is a 2-way
    // replicated posix store — the "everything at once" composition
    let mut rng = Rng::new(0xC0FFEE);
    let cases: Vec<Workload> = (0..3).map(|_| gen_workload(&mut rng)).collect();
    let run_with = |nested: bool| -> Vec<Fingerprint> {
        let dep = deploy(Testbed::Gcp, SystemKind::Lustre, 2, 2, RedundancyOpt::None);
        let SystemUnderTest::Lustre(fs) = &dep.system else {
            unreachable!()
        };
        let config = if nested {
            BackendConfig::Sharded {
                inner: Box::new(BackendConfig::Tiered {
                    front: Box::new(BackendConfig::Posix {
                        fs: fs.clone(),
                        root: "/scm".to_string(),
                    }),
                    back: Box::new(BackendConfig::Replicated {
                        inner: Box::new(BackendConfig::Posix {
                            fs: fs.clone(),
                            root: "/fdb".to_string(),
                        }),
                        copies: 2,
                    }),
                }),
                shards: 2,
            }
        } else {
            BackendConfig::Posix {
                fs: fs.clone(),
                root: "/fdb".to_string(),
            }
        };
        assert_eq!(
            config.describe(),
            if nested {
                "sharded2(tiered(posix,replicated2(posix)))"
            } else {
                "posix"
            }
        );
        let nodes = dep.client_nodes();
        cases
            .iter()
            .map(|wl| {
                let mk = |node| {
                    FdbBuilder::new(&dep.sim)
                        .node(node)
                        .backend(config.clone())
                        .build()
                        .unwrap()
                };
                let w = mk(&nodes[0]);
                let r = mk(&nodes[1]);
                run_workload(&dep.sim, w, Some(r), wl)
            })
            .collect()
    };
    assert_eq!(run_with(true), run_with(false));
}

#[test]
fn wrapper_configs_validated_recursively() {
    let sim = Sim::new();
    // zero copies / zero shards rejected
    let err = FdbBuilder::new(&sim)
        .backend(BackendConfig::Replicated {
            inner: Box::new(BackendConfig::Null),
            copies: 0,
        })
        .build()
        .err()
        .unwrap();
    assert!(matches!(err, FdbError::InvalidConfig(_)), "{err}");
    let err = FdbBuilder::new(&sim)
        .backend(BackendConfig::Sharded {
            inner: Box::new(BackendConfig::Null),
            shards: 0,
        })
        .build()
        .err()
        .unwrap();
    assert!(matches!(err, FdbError::InvalidConfig(_)), "{err}");
    // invalid INNER config caught through the wrapper: posix without a
    // node, nested two levels deep
    let dep = deploy(Testbed::Gcp, SystemKind::Lustre, 1, 1, RedundancyOpt::None);
    let SystemUnderTest::Lustre(fs) = &dep.system else {
        unreachable!()
    };
    let err = FdbBuilder::new(&dep.sim)
        .backend(BackendConfig::Tiered {
            front: Box::new(BackendConfig::Null),
            back: Box::new(BackendConfig::Replicated {
                inner: Box::new(BackendConfig::Posix {
                    fs: fs.clone(),
                    root: "relative/not/absolute".to_string(),
                }),
                copies: 2,
            }),
        })
        .build()
        .err()
        .unwrap();
    assert!(matches!(err, FdbError::InvalidConfig(_)), "{err}");
}

#[test]
fn posix_mkdir_failure_is_typed_error_not_panic() {
    // regression for the `panic!("mkdir {dir}: {e}")` site: point the
    // store's root at a regular FILE — mkdir of the dataset dir fails
    // with ENOTDIR and archive() must return FdbError::Backend
    let dep = deploy(Testbed::Gcp, SystemKind::Lustre, 1, 2, RedundancyOpt::None);
    let SystemUnderTest::Lustre(fs) = &dep.system else {
        unreachable!()
    };
    let fs2 = fs.clone();
    let node = dep.client_nodes()[0].clone();
    let mut fdb = FdbBuilder::new(&dep.sim)
        .node(&node)
        .backend(BackendConfig::Posix {
            fs: fs.clone(),
            root: "/notadir".to_string(),
        })
        .build()
        .unwrap();
    let node2 = node.clone();
    dep.sim.spawn(async move {
        let mut cli = fs2.client(&node2);
        cli.create("/notadir", fdbr::lustre::StripeSpec::default_layout())
            .await
            .unwrap();
        let id = field_id(1, 0);
        let err = fdb.archive(&id, b"payload").await.unwrap_err();
        match err {
            FdbError::Backend { backend, detail } => {
                assert_eq!(backend, "posix");
                assert!(detail.contains("mkdir"), "{detail}");
            }
            other => panic!("expected FdbError::Backend, got {other}"),
        }
        // the batched path reports the same typed error
        let batch = vec![(field_id(2, 0), Bytes::virt(64, 1))];
        let err = fdb.archive_many(batch).await.unwrap_err();
        assert!(matches!(err, FdbError::Backend { backend: "posix", .. }));
    });
    dep.sim.run();
}
