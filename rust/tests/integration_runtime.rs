//! PJRT runtime integration: load every AOT artifact, execute it, and
//! verify numerics against the Rust-side mirrors — the L1/L2 ⇄ L3
//! interchange check. Requires `make artifacts` (skips gracefully if
//! artifacts are missing so `cargo test` works pre-build).

use fdbr::runtime::{artifacts_dir, Codec, ModelStepper, PgenPipeline, PjrtRuntime};
use fdbr::workflow::fields;
use fdbr::workflow::PgenCompute;

fn have_artifacts() -> bool {
    artifacts_dir().join("pgen_e8_g32.hlo.txt").exists()
}

#[test]
fn codec_artifact_matches_rust_mirror() {
    if !have_artifacts() {
        eprintln!("skipping: run `make artifacts` first");
        return;
    }
    let rt = PjrtRuntime::cpu().unwrap();
    let codec = Codec::new(&rt, 32).unwrap();
    let field = fields::synth_field(32, 32, 42);
    let via_pjrt = codec.roundtrip(&field).unwrap();
    let via_rust = fields::unpack_simple(&fields::pack_simple(&field)).unwrap();
    // both are 16-bit quantizations of the same field: equal within the
    // combined quantization error
    let bound = 2.0 * fields::packing_error_bound(&field) + 1e-4;
    for (a, b) in via_pjrt.iter().zip(&via_rust) {
        assert!(
            (a - b).abs() <= bound,
            "pjrt {a} vs rust {b} (bound {bound})"
        );
    }
}

#[test]
fn model_step_artifact_damps_constant_field() {
    if !have_artifacts() {
        eprintln!("skipping: run `make artifacts` first");
        return;
    }
    let rt = PjrtRuntime::cpu().unwrap();
    let stepper = ModelStepper::new(&rt, 32).unwrap();
    let state = vec![10.0f32; 32 * 32];
    let noise = vec![0.0f32; 32 * 32];
    let next = stepper.step(&state, &noise).unwrap();
    // diffusion preserves a constant; damping scales by 0.98
    for v in &next {
        assert!((v - 9.8).abs() < 1e-3, "expected 9.8, got {v}");
    }
    // forcing adds 0.3 × noise
    let forced = stepper.step(&state, &vec![1.0f32; 32 * 32]).unwrap();
    for v in &forced {
        assert!((v - 10.1).abs() < 1e-3, "expected 10.1, got {v}");
    }
}

#[test]
fn pgen_artifact_products_match_direct_statistics() {
    if !have_artifacts() {
        eprintln!("skipping: run `make artifacts` first");
        return;
    }
    let rt = PjrtRuntime::cpu().unwrap();
    let pgen = PgenPipeline::new(&rt, 8, 32).unwrap();
    let gg = 32 * 32;
    let members: Vec<Vec<f32>> = (0..8)
        .map(|i| fields::synth_field(32, 32, 100 + i))
        .collect();
    let products = pgen.run(&members);
    assert_eq!(products.len(), 3); // mean, spread, prob for one group
    // direct ensemble mean
    let mut mean = vec![0.0f32; gg];
    for m in &members {
        for (acc, v) in mean.iter_mut().zip(m) {
            *acc += v / 8.0;
        }
    }
    // product[0] is the codec-roundtripped mean: compare within packing err
    let span = mean.iter().cloned().fold(f32::NEG_INFINITY, f32::max)
        - mean.iter().cloned().fold(f32::INFINITY, f32::min);
    let bound = span / 65535.0 + 1e-3;
    for (a, b) in products[0].iter().zip(&mean) {
        assert!((a - b).abs() <= bound, "mean: pjrt {a} vs direct {b}");
    }
    // probabilities in [0, 1]
    for p in &products[2] {
        assert!((0.0..=1.0).contains(p), "prob {p}");
    }
}

#[test]
fn pgen_pads_partial_groups() {
    if !have_artifacts() {
        eprintln!("skipping: run `make artifacts` first");
        return;
    }
    let rt = PjrtRuntime::cpu().unwrap();
    let pgen = PgenPipeline::new(&rt, 8, 32).unwrap();
    // 11 fields → two groups (8 + 3-padded-to-8) → 6 products
    let members: Vec<Vec<f32>> = (0..11)
        .map(|i| fields::synth_field(32, 32, 200 + i))
        .collect();
    let products = pgen.run(&members);
    assert_eq!(products.len(), 6);
    assert_eq!(pgen.invocations(), 2);
}

#[test]
fn model_integration_produces_smooth_evolution() {
    if !have_artifacts() {
        eprintln!("skipping: run `make artifacts` first");
        return;
    }
    let rt = PjrtRuntime::cpu().unwrap();
    let stepper = ModelStepper::new(&rt, 32).unwrap();
    let mut state = fields::synth_field(32, 32, 7);
    for step in 0..10 {
        let noise = fields::synth_field(32, 32, 1000 + step);
        state = stepper.step(&state, &noise).unwrap();
        assert!(state.iter().all(|v| v.is_finite()), "step {step} diverged");
    }
    let max = state.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
    assert!(max < 200.0, "model should stay bounded, max {max}");
}
