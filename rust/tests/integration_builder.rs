//! FdbBuilder/BackendConfig integration tests: every backend is
//! constructible from its config, invalid configs are rejected with
//! typed errors, and the batched `archive_many` / `retrieve_many` paths
//! are equivalent to the one-at-a-time API.

use std::rc::Rc;

use fdbr::bench::scenario::{deploy, RedundancyOpt, SystemKind, SystemUnderTest};
use fdbr::fdb::schema::example_identifier;
use fdbr::fdb::{BackendConfig, DataHandle, FdbBuilder, FdbError, Key, Request};
use fdbr::hw::profiles::Testbed;
use fdbr::util::content::Bytes;

fn id_step(step: u32) -> Key {
    example_identifier().with("step", step.to_string())
}

fn seed_of(id: &Key) -> u64 {
    fdbr::ceph::hash_name(&id.canonical())
}

#[test]
fn builder_rejects_invalid_configs() {
    let dep = deploy(Testbed::Gcp, SystemKind::Lustre, 1, 1, RedundancyOpt::None);
    let SystemUnderTest::Lustre(fs) = &dep.system else {
        unreachable!()
    };
    let node = dep.client_nodes()[0].clone();

    // relative posix root
    let err = FdbBuilder::new(&dep.sim)
        .node(&node)
        .backend(BackendConfig::Posix {
            fs: fs.clone(),
            root: "fdb".to_string(),
        })
        .build()
        .err()
        .unwrap();
    assert!(matches!(err, FdbError::InvalidConfig(_)), "{err}");

    // posix without a client node
    let err = FdbBuilder::new(&dep.sim)
        .backend(BackendConfig::Posix {
            fs: fs.clone(),
            root: "/fdb".to_string(),
        })
        .build()
        .err()
        .unwrap();
    assert!(matches!(err, FdbError::InvalidConfig(_)), "{err}");

    // no backend at all
    let err = FdbBuilder::new(&dep.sim).node(&node).build().err().unwrap();
    assert!(matches!(err, FdbError::InvalidConfig(_)), "{err}");

    // empty daos pool label
    let daos_dep = deploy(Testbed::Gcp, SystemKind::Daos, 1, 1, RedundancyOpt::None);
    let SystemUnderTest::Daos(d) = &daos_dep.system else {
        unreachable!()
    };
    let dnode = daos_dep.client_nodes()[0].clone();
    let err = FdbBuilder::new(&daos_dep.sim)
        .node(&dnode)
        .backend(BackendConfig::Daos {
            daos: d.clone(),
            pool: String::new(),
            hash_oids: false,
        })
        .build()
        .err()
        .unwrap();
    assert!(matches!(err, FdbError::InvalidConfig(_)), "{err}");
}

#[test]
fn every_deployed_backend_constructible_and_roundtrips() {
    for kind in [SystemKind::Lustre, SystemKind::Daos, SystemKind::Ceph] {
        let dep = deploy(Testbed::Gcp, kind, 2, 2, RedundancyOpt::None);
        let nodes = dep.client_nodes();
        let mut w = dep.fdb(&nodes[0]);
        let mut r = dep.fdb(&nodes[1]);
        dep.sim.spawn(async move {
            for step in 1..=3u32 {
                let id = id_step(step);
                w.archive(&id, Bytes::virt(8 << 10, seed_of(&id)))
                    .await
                    .unwrap();
            }
            w.flush().await.expect("flush");
            w.close().await.expect("close");
            for step in 1..=3u32 {
                let id = id_step(step);
                let h = r.retrieve(&id).await.unwrap().expect("present");
                let data = r.read(&h).await.unwrap();
                assert!(
                    data.content_eq(&Bytes::virt(8 << 10, seed_of(&id))),
                    "{id}"
                );
            }
        });
        dep.sim.run();
    }
}

#[test]
fn s3_and_null_backends_constructible_from_config() {
    let dep = deploy(Testbed::Gcp, SystemKind::Lustre, 1, 2, RedundancyOpt::None);
    let server = dep.cluster.storage_nodes().next().unwrap().clone();
    let cnode = dep.client_nodes()[0].clone();
    let s3 = Rc::new(fdbr::s3::MemS3::new(&dep.sim, &server, &cnode));
    let mut s3_fdb = FdbBuilder::new(&dep.sim)
        .backend(BackendConfig::S3 {
            s3: s3.clone(),
            client_tag: "p0".to_string(),
            multipart: false,
        })
        .build()
        .unwrap();
    assert_eq!(s3_fdb.backend_names(), ("s3", "null"));
    let mut null_fdb = FdbBuilder::new(&dep.sim)
        .backend(BackendConfig::Null)
        .build()
        .unwrap();
    assert_eq!(null_fdb.backend_names(), ("null", "null"));
    dep.sim.spawn(async move {
        let id = id_step(1);
        s3_fdb.archive(&id, b"s3-bytes").await.unwrap();
        let h = s3_fdb.retrieve(&id).await.unwrap().unwrap();
        assert_eq!(s3_fdb.read(&h).await.unwrap().to_vec(), b"s3-bytes");

        null_fdb.archive(&id, b"null-bytes").await.unwrap();
        let h = null_fdb.retrieve(&id).await.unwrap().unwrap();
        // null store delivers virtual bytes of matching length only
        assert_eq!(null_fdb.read(&h).await.unwrap().len(), 10);
    });
    dep.sim.run();
}

#[test]
fn null_catalogue_list_survives_lossy_keys() {
    // a param value with '=' and ',' breaks canonical→parse round-trips;
    // the Key-typed Null catalogue must list it anyway
    let sim = fdbr::sim::exec::Sim::new();
    let mut fdb = FdbBuilder::new(&sim)
        .backend(BackendConfig::Null)
        .build()
        .unwrap();
    sim.spawn(async move {
        let id = example_identifier().with("param", "a=b,c");
        fdb.archive(&id, b"payload").await.unwrap();
        let ds = id.project(&fdb.schema.dataset.clone()).unwrap();
        let listed = fdb.list(&ds, &Request::parse("").unwrap()).await;
        assert_eq!(listed.len(), 1, "lossy key must not be dropped");
        assert_eq!(listed[0].0, id);
        // and the full stats path sees it too
        let stats = fdb.stats(&ds).await;
        assert_eq!(stats.fields, 1);
    });
    sim.run();
}

#[test]
fn archive_many_equivalent_to_loop() {
    let dep = deploy(Testbed::Gcp, SystemKind::Daos, 2, 2, RedundancyOpt::None);
    let nodes = dep.client_nodes();
    let mut batch_writer = dep.fdb(&nodes[0]);
    let mut loop_writer = dep.fdb(&nodes[0]);
    let mut reader = dep.fdb(&nodes[1]);
    dep.sim.spawn(async move {
        // steps 1..=8 via one archive_many; steps 11..=18 one at a time
        let batch: Vec<(Key, Bytes)> = (1..=8u32)
            .map(|s| {
                let id = id_step(s);
                let data = Bytes::virt(16 << 10, seed_of(&id));
                (id, data)
            })
            .collect();
        batch_writer.archive_many(batch).await.unwrap();
        batch_writer.flush().await.expect("flush");
        batch_writer.close().await.expect("close");
        for s in 11..=18u32 {
            let id = id_step(s);
            loop_writer
                .archive(&id, Bytes::virt(16 << 10, seed_of(&id)))
                .await
                .unwrap();
        }
        loop_writer.flush().await.expect("flush");
        loop_writer.close().await.expect("close");
        // every field from both paths retrievable with identical bytes
        for s in (1..=8u32).chain(11..=18u32) {
            let id = id_step(s);
            let h = reader.retrieve(&id).await.unwrap().expect("present");
            let data = reader.read(&h).await.unwrap();
            assert!(
                data.content_eq(&Bytes::virt(16 << 10, seed_of(&id))),
                "step {s}"
            );
        }
        let ds = id_step(1).project(&reader.schema.dataset.clone()).unwrap();
        let listed = reader.list(&ds, &Request::parse("").unwrap()).await;
        assert_eq!(listed.len(), 16, "both paths index exactly once per id");
    });
    dep.sim.run();
}

#[test]
fn retrieve_many_equivalent_to_retrieve_loop() {
    for kind in [SystemKind::Lustre, SystemKind::Daos] {
        let dep = deploy(Testbed::Gcp, kind, 2, 2, RedundancyOpt::None);
        let nodes = dep.client_nodes();
        let mut w = dep.fdb(&nodes[0]);
        let mut r_batch = dep.fdb(&nodes[1]);
        let mut r_loop = dep.fdb(&nodes[1]);
        dep.sim.spawn(async move {
            let ids: Vec<Key> = (1..=10u32).map(id_step).collect();
            for id in &ids {
                w.archive(id, Bytes::virt(32 << 10, seed_of(id)))
                    .await
                    .unwrap();
            }
            w.flush().await.expect("flush");
            w.close().await.expect("close");
            // one absent id mixed in: both paths must skip it silently
            let mut ask = ids.clone();
            ask.push(id_step(999));
            let batched = r_batch.retrieve_many(&ask).await.unwrap();
            let mut looped = Vec::new();
            for id in &ask {
                if let Some(h) = r_loop.retrieve(id).await.unwrap() {
                    looped.push((id.clone(), r_loop.read(&h).await.unwrap()));
                }
            }
            assert_eq!(batched.len(), ids.len(), "{kind:?}");
            assert_eq!(batched.len(), looped.len(), "{kind:?}");
            for ((bid, bbytes), (lid, lbytes)) in batched.iter().zip(&looped) {
                assert_eq!(bid, lid, "{kind:?}: same order");
                assert!(bbytes.content_eq(lbytes), "{kind:?}: same bytes for {bid}");
                assert!(
                    bbytes.content_eq(&Bytes::virt(32 << 10, seed_of(bid))),
                    "{kind:?}: correct bytes for {bid}"
                );
            }
        });
        dep.sim.run();
    }
}

#[test]
fn hash_oid_mode_via_builder_bypasses_catalogue() {
    let dep = deploy(Testbed::Gcp, SystemKind::Daos, 2, 2, RedundancyOpt::None);
    let SystemUnderTest::Daos(d) = &dep.system else {
        unreachable!()
    };
    let nodes = dep.client_nodes();
    let mk = |node: &Rc<fdbr::hw::node::Node>| {
        FdbBuilder::new(&dep.sim)
            .node(node)
            .backend(BackendConfig::Daos {
                daos: d.clone(),
                pool: "fdb".to_string(),
                hash_oids: true,
            })
            .build()
            .unwrap()
    };
    let mut w = mk(&nodes[0]);
    let mut r = mk(&nodes[1]);
    dep.sim.spawn(async move {
        let ids: Vec<Key> = (1..=5u32).map(id_step).collect();
        for id in &ids {
            w.archive(id, Bytes::virt(4 << 10, seed_of(id))).await.unwrap();
        }
        // no flush needed on DAOS; hash-OID retrieve skips the index
        for id in &ids {
            let h = r.retrieve(id).await.unwrap().expect("direct retrieve");
            let data = r.read(&h).await.unwrap();
            assert!(data.content_eq(&Bytes::virt(4 << 10, seed_of(id))));
        }
        // the batched path uses the sequential direct-lookup route
        let fetched = r.retrieve_many(&ids).await.unwrap();
        assert_eq!(fetched.len(), ids.len());
    });
    dep.sim.run();
}

#[test]
fn mismatched_handle_is_typed_error() {
    let dep = deploy(Testbed::Gcp, SystemKind::Daos, 2, 2, RedundancyOpt::None);
    let node = dep.client_nodes()[0].clone();
    let mut fdb = dep.fdb(&node);
    dep.sim.spawn(async move {
        let handle = DataHandle::Posix {
            path: "/fdb/other".to_string(),
            ranges: vec![(0, 128)],
        };
        let err = fdb.read(&handle).await.unwrap_err();
        assert_eq!(
            err,
            FdbError::BackendMismatch {
                store: "daos",
                handle: "posix",
            }
        );
        // the error formats with both backend names
        assert!(err.to_string().contains("daos") && err.to_string().contains("posix"));
    });
    dep.sim.run();
}
