//! Integrity-scenario integration tests behind `fdbctl fsck`:
//!
//! * the **interrupted wipe**: a crash between the store half of
//!   `fdb-wipe` and the catalogue deregistration leaves every entry a
//!   ghost — fsck must detect the whole class, `--repair` must converge
//!   (second pass clean), and no entry may resolve afterwards;
//! * the **nested-stack repair property**: over random workloads on the
//!   full recursive composition `sharded(tiered(posix,
//!   replicated(posix)))` with every front-tier copy rotten on disk,
//!   `fsck --repair` heals the front from the back tier's write-through
//!   copies and the repaired dataset reads back byte-identical to the
//!   same workload on the no-fault stack.

use std::cell::RefCell;
use std::rc::Rc;

use fdbr::bench::scenario::{deploy, RedundancyOpt, SystemKind, SystemUnderTest};
use fdbr::fdb::fault::{FaultAction, FaultClass, FaultPlan};
use fdbr::fdb::{BackendConfig, FdbBuilder, FsckReport, Key, Store};
use fdbr::hw::profiles::Testbed;
use fdbr::lustre::Lustre;
use fdbr::util::content::Bytes;
use fdbr::util::prop;

/// Field `i` of collocation group `g`: the stock POSIX schema
/// collocates on `type,levtype`, so a per-group `levtype` gives each
/// group its own container file.
fn group_id(g: usize, i: usize) -> Key {
    fdbr::bench::hammer::field_id(0, 1 + i as u32, 0, 0).with("levtype", format!("l{g}"))
}

#[test]
fn interrupted_wipe_ghost_state_fsck_repair_converges() {
    let dep = deploy(Testbed::Gcp, SystemKind::Lustre, 2, 2, RedundancyOpt::None);
    let SystemUnderTest::Lustre(fs) = &dep.system else {
        unreachable!()
    };
    let config = BackendConfig::Posix {
        fs: fs.clone(),
        root: "/fdb".to_string(),
    };
    let nodes = dep.client_nodes();
    let mut w = FdbBuilder::new(&dep.sim)
        .node(&nodes[0])
        .backend(config.clone())
        .build()
        .unwrap();
    let sim2 = dep.sim.clone();
    let opnode = nodes[1].clone();
    let out = Rc::new(RefCell::new((
        FsckReport::default(),
        FsckReport::default(),
        0usize,
    )));
    let out2 = out.clone();
    dep.sim.spawn(async move {
        // two collocation groups → two container files on disk
        let ids: Vec<Key> = (0..8).map(|i| group_id(i / 4, i % 4)).collect();
        for (i, id) in ids.iter().enumerate() {
            w.archive(id, Bytes::virt(256, i as u64)).await.unwrap();
        }
        w.flush().await.unwrap();
        w.close().await.unwrap();
        let ds = ids[0].project(&w.schema.dataset.clone()).unwrap();
        // `fdb-wipe` is one store wipe followed by one catalogue
        // deregistration. Crash the process between the two: every
        // container is gone from the data path while the catalogue
        // still lists all entries. (Seeded via per-container
        // quarantine — the store half of the wipe — because on POSIX
        // the dataset directory is shared with the catalogue, whose
        // TOC/index files a mid-wipe crash would also leave behind.)
        let (store, _) = w.backend_mut();
        let inventory = store
            .scrub_inventory(&ds)
            .await
            .expect("posix stores can inventory");
        assert_eq!(inventory.len(), 2, "one container per collocation group");
        for (container, _len) in &inventory {
            let gone = store.quarantine_object(&ds, container).await.unwrap();
            assert!(gone, "wipe half must remove {container}");
        }
        drop(w); // the crashed process

        // a fresh operator instance finds and repairs the ghost state
        let mut op = FdbBuilder::new(&sim2)
            .node(&opnode)
            .backend(config)
            .build()
            .unwrap();
        let first = op.fsck(&ds, true).await.expect("fsck --repair");
        let second = op.fsck(&ds, false).await.expect("fsck convergence pass");
        let mut found = 0usize;
        for id in &ids {
            if op.retrieve(id).await.unwrap().is_some() {
                found += 1;
            }
        }
        *out2.borrow_mut() = (first, second, found);
    });
    dep.sim.run();
    let (first, second, found) = *out.borrow();
    assert_eq!(first.entries, 8);
    assert_eq!(first.ghosts, 8, "every surviving entry is a ghost");
    assert_eq!(first.ghosts_dropped, 8, "repair drops the whole class");
    assert_eq!(first.corrupt, 0);
    assert_eq!(
        first.orphans, 0,
        "wiped containers are gone from the inventory, not orphaned"
    );
    assert!(first.converged(), "repair must converge: {first}");
    assert!(second.clean(), "second pass must be clean: {second}");
    assert_eq!(second.entries, 0, "the catalogue caught up with the wipe");
    assert_eq!(found, 0, "no ghost entry resolves after repair");
}

/// One randomized workload: fields addressed by (step, param) with
/// per-field payload sizes. Repeats re-archive (replace) the field.
#[derive(Clone, Debug)]
struct Workload {
    fields: Vec<(u32, u32, u64)>,
}

fn gen_workload(rng: &mut fdbr::util::rng::Rng) -> Workload {
    let n = 1 + rng.below(12) as usize;
    let fields = (0..n)
        .map(|_| {
            (
                1 + rng.below(4) as u32,
                rng.below(3) as u32,
                64 + rng.below(4096),
            )
        })
        .collect();
    Workload { fields }
}

fn payload(step: u32, param: u32, size: u64) -> Bytes {
    Bytes::virt(size, (u64::from(step) << 32) | u64::from(param))
}

/// FNV-1a over materialized bytes (payloads here are tiny).
fn digest(bytes: &[u8]) -> u64 {
    let mut h: u64 = 0xcbf29ce484222325;
    for &b in bytes {
        h ^= u64::from(b);
        h = h.wrapping_mul(0x100000001b3);
    }
    h
}

/// The "everything at once" composition: a sharded catalogue over a
/// tiered store whose back tier is 2-way replicated posix. With `rot`,
/// a fault layer on the FRONT leaf corrupts every front-tier write of
/// the first store built from it (`only_instance(0)` = the writer —
/// the catalogue is built from the back config, so it never advances
/// this layer's counter).
fn nested_config(fs: &Rc<Lustre>, rot: bool) -> BackendConfig {
    let mut front = BackendConfig::Posix {
        fs: fs.clone(),
        root: "/scm".to_string(),
    };
    if rot {
        front = BackendConfig::Fault {
            inner: Box::new(front),
            plan: FaultPlan::new(0xD15C_0707)
                .with_rule(FaultClass::Write, FaultAction::Corrupt { prob: 1.0 })
                .with_only_instance(0),
        };
    }
    BackendConfig::Sharded {
        inner: Box::new(BackendConfig::Tiered {
            front: Box::new(front),
            back: Box::new(BackendConfig::Replicated {
                inner: Box::new(BackendConfig::Posix {
                    fs: fs.clone(),
                    root: "/fdb".to_string(),
                }),
                copies: 2,
            }),
        }),
        shards: 2,
    }
}

/// Run one workload on the nested stack: writer archives (flush +
/// close), then — on the `rot` leg — the writer runs `fsck --repair`
/// plus a detect-only convergence pass (asserting every referenced
/// front copy was found rotten and repaired), and finally a fresh
/// reader on the second node fingerprints every unique field.
fn nested_fingerprint(rot: bool, wl: &Workload) -> Vec<(String, u64, u64)> {
    let dep = deploy(Testbed::Gcp, SystemKind::Lustre, 2, 2, RedundancyOpt::None);
    let SystemUnderTest::Lustre(fs) = &dep.system else {
        unreachable!()
    };
    let config = nested_config(fs, rot);
    if rot {
        assert!(config.describe().contains("fault["), "{}", config.describe());
    } else {
        assert_eq!(config.describe(), "sharded2(tiered(posix,replicated2(posix)))");
    }
    let nodes = dep.client_nodes();
    // build order matters: the writer's front store is fault instance 0
    let mut w = FdbBuilder::new(&dep.sim)
        .node(&nodes[0])
        .backend(config.clone())
        .build()
        .unwrap();
    let mut r = FdbBuilder::new(&dep.sim)
        .node(&nodes[1])
        .backend(config)
        .build()
        .unwrap();
    let out = Rc::new(RefCell::new(Vec::new()));
    let out2 = out.clone();
    let wl = wl.clone();
    dep.sim.spawn(async move {
        let mut ids: Vec<Key> = Vec::new();
        let mut seen = std::collections::BTreeSet::new();
        for &(step, param, size) in &wl.fields {
            let id = fdbr::bench::hammer::field_id(0, step, param, 0);
            w.archive(&id, payload(step, param, size)).await.unwrap();
            if seen.insert(id.canonical()) {
                ids.push(id);
            }
        }
        w.flush().await.unwrap();
        w.close().await.expect("close");
        let ds = ids[0].project(&w.schema.dataset.clone()).unwrap();
        if rot {
            // fsck on the WRITER: its tiered store recorded the
            // spill-time back-tier locations repair rewrites from
            let n = ids.len() as u64;
            let first = w.fsck(&ds, true).await.expect("fsck --repair");
            assert_eq!(first.entries, n);
            assert_eq!(first.verified, n, "every entry carries a checksum");
            assert_eq!(first.corrupt, n, "every referenced front copy is rotten");
            assert_eq!(
                first.repaired, n,
                "every front copy rewritten from its back-tier spill copy"
            );
            assert_eq!(first.ghosts, 0);
            assert_eq!(first.orphans, 0);
            assert!(first.converged(), "repair must converge: {first}");
            let second = w.fsck(&ds, false).await.expect("convergence pass");
            assert!(second.clean(), "second pass must be clean: {second}");
        } else {
            // the healthy stack scrubs clean in the first place
            let report = w.fsck(&ds, false).await.expect("fsck");
            assert!(report.clean(), "healthy stack must fsck clean: {report}");
        }
        // fingerprint through a fresh reader (its front store is fault
        // instance 1 — out of the `only_instance(0)` scope, so what it
        // observes is exactly what is on disk after repair)
        let mut fp = Vec::new();
        for id in &ids {
            let h = r
                .retrieve(id)
                .await
                .unwrap()
                .unwrap_or_else(|| panic!("missing {id}"));
            let bytes = r.read(&h).await.unwrap().to_vec();
            fp.push((id.canonical(), bytes.len() as u64, digest(&bytes)));
        }
        *out2.borrow_mut() = fp;
    });
    dep.sim.run();
    let fp = out.borrow().clone();
    fp
}

#[test]
fn nested_stack_repair_is_byte_identical_to_no_fault_baseline() {
    // property: for random workloads, rotting EVERY front-tier copy on
    // disk and then running `fsck --repair` yields a dataset that reads
    // back byte-identical to the same workload on the no-fault stack
    prop::check_no_shrink(0x5C12B, 3, gen_workload, |wl| {
        let baseline = nested_fingerprint(false, wl);
        assert!(!baseline.is_empty(), "workload must index at least one field");
        let healed = nested_fingerprint(true, wl);
        assert_eq!(
            healed, baseline,
            "repaired nested stack must be byte-identical to the baseline"
        );
        true
    });
}
