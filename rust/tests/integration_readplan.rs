//! Read-plan coalescing equivalence and accounting tests.
//!
//! Property: for ANY `(coalesce_gap, coalesce_max, depth)` combination,
//! the batched `retrieve_many` path returns **byte- and order-identical**
//! results to the uncoalesced depth-1 legacy path — over the Null pair,
//! bare POSIX/Lustre, spanned RADOS (the object shape that genuinely
//! merges), and the recursive sharded(tiered(posix, replicated(posix)))
//! stack — only the I/O op count (and so the virtual time) may change.
//! Plus: merged ranges (not raw fields) are the unit the depth
//! semaphore admits, and the planner's `ops_merged`/`ops_out` counters
//! match the `DataRead` ops the trace actually saw.

use std::cell::RefCell;
use std::rc::Rc;

use fdbr::bench::scenario::{deploy, RedundancyOpt, SystemKind, SystemUnderTest};
use fdbr::fdb::rados::store::{RadosLayout, RadosStoreConfig};
use fdbr::fdb::{BackendConfig, Fdb, FdbBuilder, FdbError, IoProfile, Key, PlanStats};
use fdbr::hw::profiles::Testbed;
use fdbr::sim::exec::Sim;
use fdbr::sim::trace::{OpClass, Trace};
use fdbr::util::content::Bytes;
use fdbr::util::rng::Rng;

/// One randomized dense-ish workload: fields addressed by (step, param)
/// with per-field payload sizes (coalescible runs arise naturally since
/// one process appends them in order).
#[derive(Clone, Debug)]
struct Workload {
    fields: Vec<(u32, u32, u64)>,
}

fn gen_workload(rng: &mut Rng) -> Workload {
    let n = 2 + rng.below(14) as usize;
    let fields = (0..n)
        .map(|_| {
            (
                1 + rng.below(4) as u32,
                rng.below(4) as u32,
                128 + rng.below(8192),
            )
        })
        .collect();
    Workload { fields }
}

/// The knob combinations a backend is swept over, against the
/// `(gap 0, depth 1)` baseline: gap × cap × queue depth.
fn combos() -> Vec<IoProfile> {
    let mut out = Vec::new();
    for gap in [1u64, 64 << 10, 1 << 20] {
        for max in [0u64, 3000, 8 << 20] {
            for depth in [1usize, 4] {
                if max != 0 && gap >= max {
                    continue; // rejected by validation
                }
                out.push(
                    IoProfile::depth(depth)
                        .with_coalesce_gap(gap)
                        .with_coalesce_max(max),
                );
            }
        }
    }
    out
}

fn field_id(step: u32, param: u32) -> Key {
    fdbr::bench::hammer::field_id(0, step, param, 0)
}

fn payload(step: u32, param: u32, size: u64) -> Bytes {
    Bytes::virt(
        size,
        (u64::from(step) << 32) | (u64::from(param) << 8) | (size & 0xff),
    )
}

/// FNV-1a over materialized bytes (payloads here are tiny).
fn digest(bytes: &[u8]) -> u64 {
    let mut h: u64 = 0xcbf29ce484222325;
    for &b in bytes {
        h ^= u64::from(b);
        h = h.wrapping_mul(0x100000001b3);
    }
    h
}

/// Everything observable after the batched cycle, in order.
#[derive(Clone, Debug, Default, PartialEq, Eq)]
struct Fingerprint {
    fetched: Vec<(String, u64, u64)>,
}

/// Archive the workload through `w` (one `archive_many` batch + flush +
/// close), then fetch every unique identifier in one `retrieve_many`
/// through `r`. Returns the ordered fingerprint plus the reader's
/// cumulative plan stats and in-flight peak.
fn run_batched(sim: &Sim, w: Fdb, r: Fdb, wl: &Workload) -> (Fingerprint, PlanStats, usize) {
    let out = Rc::new(RefCell::new((Fingerprint::default(), PlanStats::default(), 0)));
    let out2 = out.clone();
    let wl = wl.clone();
    let mut w = w;
    let mut r = r;
    sim.spawn(async move {
        let mut batch: Vec<(Key, Bytes)> = Vec::new();
        let mut ids: Vec<Key> = Vec::new();
        let mut seen = std::collections::BTreeSet::new();
        for &(step, param, size) in &wl.fields {
            let id = field_id(step, param);
            batch.push((id.clone(), payload(step, param, size)));
            if seen.insert(id.canonical()) {
                ids.push(id);
            }
        }
        let depth = r.io_profile().depth;
        w.archive_many(batch).await.unwrap();
        w.flush().await.unwrap();
        w.close().await.expect("close");
        let fetched = r.retrieve_many(&ids).await.unwrap();
        let mut fp = Fingerprint::default();
        for (id, bytes) in &fetched {
            let v = bytes.to_vec();
            fp.fetched.push((id.canonical(), v.len() as u64, digest(&v)));
        }
        assert!(
            r.io_inflight_peak() <= depth.max(1),
            "in-flight peak {} exceeds depth {}",
            r.io_inflight_peak(),
            depth
        );
        *out2.borrow_mut() = (fp, r.plan_stats(), r.io_inflight_peak());
    });
    sim.run();
    let got = out.borrow().clone();
    got
}

fn assert_combo_equivalence<F>(cases: &[Workload], fingerprints: F, what: &str)
where
    F: Fn(IoProfile) -> Vec<(Fingerprint, PlanStats)>,
{
    let base = fingerprints(IoProfile::depth(1));
    assert!(
        base.iter().all(|(fp, _)| !fp.fetched.is_empty()),
        "{what}: baseline must fetch fields"
    );
    assert_eq!(base.len(), cases.len());
    for io in combos() {
        let got = fingerprints(io);
        for ((fp, stats), (base_fp, _)) in got.iter().zip(&base) {
            assert_eq!(
                fp, base_fp,
                "{what} at gap={} max={} depth={} must be byte- and order-identical \
                 to the uncoalesced depth-1 path",
                io.coalesce_gap, io.coalesce_max, io.depth
            );
            // plan bookkeeping is self-consistent on every combo
            assert_eq!(stats.ops_in, stats.ops_out + stats.ops_merged);
        }
    }
}

#[test]
fn any_combo_equals_uncoalesced_depth_one_over_null() {
    let mut rng = Rng::new(0xC0A1);
    let cases: Vec<Workload> = (0..3).map(|_| gen_workload(&mut rng)).collect();
    let fingerprints = |io: IoProfile| -> Vec<(Fingerprint, PlanStats)> {
        cases
            .iter()
            .map(|wl| {
                let sim = Sim::new();
                let mk = || {
                    FdbBuilder::new(&sim)
                        .backend(BackendConfig::Null)
                        .io(io)
                        .build()
                        .unwrap()
                };
                let w = mk();
                // process-local Null catalogue: the writer reads back
                let sim2 = sim.clone();
                let (fp, stats, _) = run_batched_same(&sim2, w, wl);
                (fp, stats)
            })
            .collect()
    };
    assert_combo_equivalence(&cases, fingerprints, "Null");
}

/// Null variant where the writer is also the reader (process-local
/// catalogue).
fn run_batched_same(sim: &Sim, w: Fdb, wl: &Workload) -> (Fingerprint, PlanStats, usize) {
    let out = Rc::new(RefCell::new((Fingerprint::default(), PlanStats::default(), 0)));
    let out2 = out.clone();
    let wl = wl.clone();
    let mut w = w;
    sim.spawn(async move {
        let mut batch: Vec<(Key, Bytes)> = Vec::new();
        let mut ids: Vec<Key> = Vec::new();
        let mut seen = std::collections::BTreeSet::new();
        for &(step, param, size) in &wl.fields {
            let id = field_id(step, param);
            batch.push((id.clone(), payload(step, param, size)));
            if seen.insert(id.canonical()) {
                ids.push(id);
            }
        }
        w.archive_many(batch).await.unwrap();
        w.flush().await.unwrap();
        w.close().await.expect("close");
        let fetched = w.retrieve_many(&ids).await.unwrap();
        let mut fp = Fingerprint::default();
        for (id, bytes) in &fetched {
            let v = bytes.to_vec();
            fp.fetched.push((id.canonical(), v.len() as u64, digest(&v)));
        }
        *out2.borrow_mut() = (fp, w.plan_stats(), w.io_inflight_peak());
    });
    sim.run();
    let got = out.borrow().clone();
    got
}

#[test]
fn any_combo_equals_uncoalesced_depth_one_over_posix() {
    let mut rng = Rng::new(0xC0A2);
    let cases: Vec<Workload> = (0..3).map(|_| gen_workload(&mut rng)).collect();
    let fingerprints = |io: IoProfile| -> Vec<(Fingerprint, PlanStats)> {
        let dep = deploy(Testbed::Gcp, SystemKind::Lustre, 2, 2, RedundancyOpt::None)
            .with_io(io);
        let nodes = dep.client_nodes();
        cases
            .iter()
            .map(|wl| {
                let w = dep.fdb(&nodes[0]);
                let r = dep.fdb(&nodes[1]);
                let (fp, stats, _) = run_batched(&dep.sim, w, r, wl);
                (fp, stats)
            })
            .collect()
    };
    assert_combo_equivalence(&cases, fingerprints, "POSIX/Lustre");
}

#[test]
fn any_combo_equals_uncoalesced_depth_one_over_spanned_rados() {
    let mut rng = Rng::new(0xC0A3);
    let cases: Vec<Workload> = (0..2).map(|_| gen_workload(&mut rng)).collect();
    let fingerprints = |io: IoProfile| -> Vec<(Fingerprint, PlanStats)> {
        let dep = deploy(Testbed::Gcp, SystemKind::Ceph, 2, 2, RedundancyOpt::None);
        let SystemUnderTest::Ceph(ceph, pool) = &dep.system else {
            unreachable!()
        };
        let nodes = dep.client_nodes();
        let mk = |node| {
            FdbBuilder::new(&dep.sim)
                .node(node)
                .backend(BackendConfig::Rados {
                    ceph: ceph.clone(),
                    pool: pool.clone(),
                    store: RadosStoreConfig {
                        layout: RadosLayout::SpannedPerProcess,
                        ..Default::default()
                    },
                })
                .io(io)
                .build()
                .unwrap()
        };
        cases
            .iter()
            .map(|wl| {
                let w = mk(&nodes[0]);
                let r = mk(&nodes[1]);
                let (fp, stats, _) = run_batched(&dep.sim, w, r, wl);
                (fp, stats)
            })
            .collect()
    };
    assert_combo_equivalence(&cases, fingerprints, "spanned RADOS");
}

#[test]
fn any_combo_equals_uncoalesced_depth_one_over_recursive_stack() {
    // sharded(tiered(posix, replicated(posix))): coalesced ranges must
    // compose through all three wrappers — tiered routes each range to
    // the tier that minted it, replicated applies its read policy per
    // merged range, the sharded catalogue is pass-through on the store
    let mut rng = Rng::new(0xC0A4);
    let cases: Vec<Workload> = (0..2).map(|_| gen_workload(&mut rng)).collect();
    let fingerprints = |io: IoProfile| -> Vec<(Fingerprint, PlanStats)> {
        let dep = deploy(Testbed::Gcp, SystemKind::Lustre, 2, 2, RedundancyOpt::None);
        let SystemUnderTest::Lustre(fs) = &dep.system else {
            unreachable!()
        };
        let posix = |root: &str| BackendConfig::Posix {
            fs: fs.clone(),
            root: root.to_string(),
        };
        let cfg = BackendConfig::Sharded {
            inner: Box::new(BackendConfig::Tiered {
                front: Box::new(posix("/scm")),
                back: Box::new(BackendConfig::Replicated {
                    inner: Box::new(posix("/fdb")),
                    copies: 2,
                }),
            }),
            shards: 3,
        };
        let nodes = dep.client_nodes();
        let mk = |node| {
            FdbBuilder::new(&dep.sim)
                .node(node)
                .backend(cfg.clone())
                .io(io)
                .build()
                .unwrap()
        };
        cases
            .iter()
            .map(|wl| {
                let w = mk(&nodes[0]);
                let r = mk(&nodes[1]);
                let (fp, stats, _) = run_batched(&dep.sim, w, r, wl);
                (fp, stats)
            })
            .collect()
    };
    assert_combo_equivalence(&cases, fingerprints, "sharded(tiered(posix,replicated))");
}

#[test]
fn merged_ranges_are_the_admission_unit_and_match_the_trace() {
    // a dense batch: 32 fields back-to-back in one data file. With a
    // 64 KiB gap budget the planner collapses them into a handful of
    // ranged reads; on the DEPTH > 1 fan-out path the trace's DataRead
    // count must equal the planner's ops_out (merged ranges — NOT raw
    // fields — hit the semaphore), and the in-flight peak stays under
    // the configured depth. (The depth-1 serial path instead records
    // ONE DataRead span for the whole vectored batch; `plan_stats().
    // ops_out` is the authoritative issued-op count at any depth.)
    let dep = deploy(Testbed::Gcp, SystemKind::Lustre, 2, 2, RedundancyOpt::None).with_io(
        IoProfile::depth(4)
            .with_preload_indexes(true)
            .with_coalesce_gap(64 << 10),
    );
    let nodes = dep.client_nodes();
    let trace = Trace::new();
    let mut w = dep.fdb(&nodes[0]);
    let mut r = dep.fdb_traced(&nodes[1], &trace);
    let out = Rc::new(RefCell::new((PlanStats::default(), 0usize)));
    let out2 = out.clone();
    dep.sim.spawn(async move {
        let batch: Vec<(Key, Bytes)> = (0..32u32)
            .map(|i| {
                let id = field_id(1 + i / 8, i % 8);
                (id, Bytes::virt(32 << 10, u64::from(i)))
            })
            .collect();
        let ids: Vec<Key> = batch.iter().map(|(id, _)| id.clone()).collect();
        w.archive_many(batch).await.unwrap();
        w.flush().await.unwrap();
        w.close().await.expect("close");
        let fetched = r.retrieve_many(&ids).await.unwrap();
        assert_eq!(fetched.len(), ids.len());
        *out2.borrow_mut() = (r.plan_stats(), r.io_inflight_peak());
    });
    dep.sim.run();
    let (stats, peak) = *out.borrow();
    assert_eq!(stats.ops_in, 32);
    assert!(
        stats.ops_merged > 0,
        "dense fields must merge: {stats:?}"
    );
    assert_eq!(
        trace.count(OpClass::DataRead),
        stats.ops_out,
        "DataRead ops must be the PLANNED ranges, not raw fields"
    );
    assert!(peak <= 4, "in-flight peak {peak} exceeds depth 4");
}

#[test]
fn coalesce_profile_validation() {
    // gap at or above the cap is rejected; gap below it passes
    let sim = Sim::new();
    let err = FdbBuilder::new(&sim)
        .backend(BackendConfig::Null)
        .io(IoProfile::depth(1).with_coalesce_gap(4096).with_coalesce_max(4096))
        .build()
        .unwrap_err();
    assert!(matches!(err, FdbError::InvalidConfig(_)), "{err}");
    let sim = Sim::new();
    assert!(FdbBuilder::new(&sim)
        .backend(BackendConfig::Null)
        .io(IoProfile::depth(1).with_coalesce_gap(4096).with_coalesce_max(8192))
        .build()
        .is_ok());
}
