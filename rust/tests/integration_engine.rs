//! Unified I/O-engine integration tests.
//!
//! Property: every engine path — batched archive, batched retrieve
//! (uncoalesced and streaming-coalesced), with and without catalogue
//! sessions — returns **byte- and order-identical** results to the
//! serial depth-1/gap-0 paths across a (depth × coalesce_gap ×
//! wrapper-stack) grid, with `io_inflight_peak() <= depth` covering the
//! catalogue-session lookups too. Plus the two trace-level acceptance
//! checks: catalogue lookups genuinely run at depth (the IndexRead wall
//! window is narrower than its summed busy time), and streaming plan
//! execution genuinely overlaps resolution with range issue (the first
//! DataRead span begins before the last index lookup completes). And
//! the group-commit WAL property: a durable N-field `archive_many`
//! costs ONE fdatasync barrier instead of N, yet stays exactly as
//! recoverable after a crash.

use std::cell::RefCell;
use std::rc::Rc;

use fdbr::bench::scenario::{deploy, RedundancyOpt, SystemKind, SystemUnderTest, WrapperOpt};
use fdbr::fdb::{Fdb, IoProfile, Key, Request};
use fdbr::hw::profiles::Testbed;
use fdbr::sim::exec::Sim;
use fdbr::sim::trace::{OpClass, Trace};
use fdbr::util::content::Bytes;
use fdbr::util::rng::Rng;

/// One randomized batched workload: fields addressed by (step, param)
/// with per-field payload sizes (duplicates re-archive in input order).
#[derive(Clone, Debug)]
struct Workload {
    fields: Vec<(u32, u32, u64)>,
}

fn gen_workload(rng: &mut Rng) -> Workload {
    let n = 4 + rng.below(12) as usize;
    let fields = (0..n)
        .map(|_| {
            (
                1 + rng.below(4) as u32,
                rng.below(4) as u32,
                64 + rng.below(6000),
            )
        })
        .collect();
    Workload { fields }
}

fn field_id(step: u32, param: u32) -> Key {
    fdbr::bench::hammer::field_id(0, step, param, 0)
}

fn payload(step: u32, param: u32, size: u64) -> Bytes {
    Bytes::virt(size, (u64::from(step) << 32) | (u64::from(param) << 8) | (size & 0xff))
}

/// FNV-1a over materialized bytes (payloads here are tiny).
fn digest(bytes: &[u8]) -> u64 {
    let mut h: u64 = 0xcbf29ce484222325;
    for &b in bytes {
        h ^= u64::from(b);
        h = h.wrapping_mul(0x100000001b3);
    }
    h
}

/// Everything observable after the batched cycle, **in order**.
#[derive(Clone, Debug, Default, PartialEq, Eq)]
struct Fingerprint {
    fetched: Vec<(String, u64, u64)>,
    listed: Vec<String>,
    inflight_peak_ok: bool,
    plan_invariant_ok: bool,
}

/// Archive the workload as ONE `archive_many` through `w` (flush +
/// close), then fetch every unique identifier in one `retrieve_many`
/// through `r`. Returns the ordered fingerprint.
fn run_batched(sim: &Sim, w: Fdb, r: Fdb, wl: &Workload) -> Fingerprint {
    let out = Rc::new(RefCell::new(Fingerprint::default()));
    let out2 = out.clone();
    let wl = wl.clone();
    let mut w = w;
    let mut r = r;
    sim.spawn(async move {
        let mut batch: Vec<(Key, Bytes)> = Vec::new();
        let mut ids: Vec<Key> = Vec::new();
        let mut seen = std::collections::BTreeSet::new();
        for &(step, param, size) in &wl.fields {
            let id = field_id(step, param);
            batch.push((id.clone(), payload(step, param, size)));
            if seen.insert(id.canonical()) {
                ids.push(id);
            }
        }
        let depth = w.io_profile().depth;
        w.archive_many(batch).await.unwrap();
        w.flush().await.unwrap();
        w.close().await.expect("close");
        let w_peak_ok = w.io_inflight_peak() <= depth.max(1);
        let fetched = r.retrieve_many(&ids).await.unwrap();
        let ps = r.plan_stats();
        let mut fp = Fingerprint {
            inflight_peak_ok: w_peak_ok && r.io_inflight_peak() <= depth.max(1),
            plan_invariant_ok: ps.ops_in == ps.ops_out + ps.ops_merged,
            ..Fingerprint::default()
        };
        for (id, bytes) in &fetched {
            let v = bytes.to_vec();
            fp.fetched.push((id.canonical(), v.len() as u64, digest(&v)));
        }
        let ds = ids[0].project(&r.schema.dataset.clone()).unwrap();
        let mut listed: Vec<String> = r
            .list(&ds, &Request::parse("").unwrap())
            .await
            .iter()
            .map(|(k, _)| k.canonical())
            .collect();
        listed.sort();
        fp.listed = listed;
        *out2.borrow_mut() = fp;
    });
    sim.run();
    let fp = out.borrow().clone();
    fp
}

#[test]
fn engine_grid_equals_the_serial_baseline() {
    // the satellite property: (depth × coalesce_gap × wrapper) grid —
    // every engine path must be byte- and order-identical to the
    // depth-1/gap-0 serial baseline of the same stack, with the
    // in-flight peak (catalogue-session lookups included, they share
    // the one semaphore) bounded by the configured depth throughout
    let mut rng = Rng::new(0xE2612E);
    let cases: Vec<Workload> = (0..2).map(|_| gen_workload(&mut rng)).collect();
    let stacks = [
        WrapperOpt::Bare,
        WrapperOpt::Replicated(2),
        WrapperOpt::Sharded(3),
    ];
    for wrapper in stacks {
        let fingerprints = |depth: usize, gap: u64| -> Vec<Fingerprint> {
            let io = IoProfile::depth(depth).with_coalesce_gap(gap);
            let dep = deploy(Testbed::Gcp, SystemKind::Lustre, 2, 2, RedundancyOpt::None)
                .with_wrapper(wrapper)
                .with_io(io);
            let nodes = dep.client_nodes();
            cases
                .iter()
                .map(|wl| {
                    let w = dep.fdb(&nodes[0]);
                    let r = dep.fdb(&nodes[1]);
                    run_batched(&dep.sim, w, r, wl)
                })
                .collect()
        };
        let base = fingerprints(1, 0);
        assert!(base.iter().all(|fp| !fp.fetched.is_empty()));
        for depth in [1usize, 2, 4] {
            for gap in [0u64, 64 << 10] {
                if depth == 1 && gap == 0 {
                    continue;
                }
                assert_eq!(
                    fingerprints(depth, gap),
                    base,
                    "{wrapper:?} depth {depth} gap {gap} must match the serial baseline"
                );
            }
        }
    }
}

#[test]
fn catalogue_lookups_run_at_the_configured_depth() {
    // acceptance criterion: at depth > 1 with catalogue sessions, the
    // batched lookups themselves fan out. Trace evidence: the IndexRead
    // wall window (earliest start to latest end, raw) is strictly
    // narrower than the summed IndexRead busy time — impossible for any
    // serial lookup schedule, which always has window >= total.
    let dep = deploy(Testbed::Gcp, SystemKind::Lustre, 2, 2, RedundancyOpt::None)
        .with_io(IoProfile::depth(4));
    let nodes = dep.client_nodes();
    let mut w = dep.fdb(&nodes[0]);
    let trace = Trace::new();
    let mut r = dep.fdb_traced(&nodes[1], &trace);
    let checked = Rc::new(RefCell::new(false));
    let checked2 = checked.clone();
    dep.sim.spawn(async move {
        let batch: Vec<(Key, Bytes)> = (0..24u32)
            .map(|i| (field_id(1 + i / 8, i % 8), Bytes::virt(16 << 10, u64::from(i))))
            .collect();
        let ids: Vec<Key> = batch.iter().map(|(id, _)| id.clone()).collect();
        w.archive_many(batch).await.unwrap();
        w.flush().await.unwrap();
        w.close().await.expect("close");
        let fetched = r.retrieve_many(&ids).await.unwrap();
        assert_eq!(fetched.len(), ids.len());
        assert_eq!(r.io_sessions(), 4, "full store-session pool");
        assert!(r.io_inflight_peak() <= 4, "peak {}", r.io_inflight_peak());
        *checked2.borrow_mut() = true;
    });
    dep.sim.run();
    assert!(*checked.borrow(), "scenario ran");
    assert_eq!(trace.count(OpClass::IndexRead), 24, "one lookup per field");
    let (start, end) = trace
        .span_window(OpClass::IndexRead)
        .expect("engine lookups record raw windows");
    let window = end - start;
    let total = trace.total(OpClass::IndexRead);
    assert!(
        window < total,
        "lookups never overlapped: window {:?} >= busy total {:?}",
        window,
        total
    );
}

#[test]
fn streaming_issues_ranges_while_lookups_still_resolve() {
    // acceptance criterion for streaming plan execution: the first
    // DataRead span begins BEFORE the last index lookup completes at
    // depth > 1 — resolve overlaps execute instead of forming a
    // barrier. coalesce_max is set just above the field size so every
    // run seals (and becomes issuable) the moment its successor
    // resolves, not at end-of-batch.
    let field = 64u64 << 10;
    let dep = deploy(Testbed::Gcp, SystemKind::Lustre, 2, 2, RedundancyOpt::None).with_io(
        IoProfile::depth(4)
            .with_coalesce_gap(4096)
            .with_coalesce_max(field + (32 << 10)),
    );
    let nodes = dep.client_nodes();
    let mut w = dep.fdb(&nodes[0]);
    let trace = Trace::new();
    let mut r = dep.fdb_traced(&nodes[1], &trace);
    let checked = Rc::new(RefCell::new(false));
    let checked2 = checked.clone();
    dep.sim.spawn(async move {
        let batch: Vec<(Key, Bytes)> = (0..24u32)
            .map(|i| (field_id(1 + i / 8, i % 8), Bytes::virt(field, u64::from(i))))
            .collect();
        let ids: Vec<Key> = batch.iter().map(|(id, _)| id.clone()).collect();
        w.archive_many(batch).await.unwrap();
        w.flush().await.unwrap();
        w.close().await.expect("close");
        let fetched = r.retrieve_many(&ids).await.unwrap();
        assert_eq!(fetched.len(), ids.len());
        for (i, (id, bytes)) in fetched.iter().enumerate() {
            assert_eq!(id, &ids[i], "input order preserved");
            assert!(
                bytes.content_eq(&Bytes::virt(field, i as u64)),
                "byte-identical payload for {id}"
            );
        }
        let ps = r.plan_stats();
        assert_eq!(ps.ops_in, 24, "every field entered the planner");
        assert_eq!(
            ps.ops_in,
            ps.ops_out + ps.ops_merged,
            "plan counters must balance"
        );
        assert!(r.io_inflight_peak() <= 4, "peak {}", r.io_inflight_peak());
        *checked2.borrow_mut() = true;
    });
    dep.sim.run();
    assert!(*checked.borrow(), "scenario ran");
    let (first_read, _) = trace
        .span_window(OpClass::DataRead)
        .expect("streaming workers record raw windows");
    let (_, last_lookup) = trace
        .span_window(OpClass::IndexRead)
        .expect("engine lookups record raw windows");
    assert!(
        first_read < last_lookup,
        "no resolve/execute overlap: first data read at {:?}, lookups done at {:?}",
        first_read,
        last_lookup
    );
}

#[test]
fn group_commit_syncs_each_wal_once_per_batch() {
    // satellite: a durable N-field batch inside an archive group costs
    // ONE fdatasync barrier on the dataset's WAL; the same N fields as
    // bare archives cost N. Counted directly on the POSIX catalogue.
    let dep = deploy(Testbed::Gcp, SystemKind::Lustre, 2, 2, RedundancyOpt::None);
    let SystemUnderTest::Lustre(fs) = &dep.system else {
        unreachable!()
    };
    let node = dep.client_nodes()[0].clone();
    let schema = fdbr::fdb::Schema::default_posix();
    let mut grouped = fdbr::fdb::posix::catalogue::PosixCatalogue::new(
        fs.client(&node),
        "/idx-grouped",
        schema.clone(),
    )
    .with_durable(true);
    let mut bare = fdbr::fdb::posix::catalogue::PosixCatalogue::new(
        fs.client(&node),
        "/idx-bare",
        schema.clone(),
    )
    .with_durable(true);
    let counts = Rc::new(RefCell::new((0u64, 0u64)));
    let counts2 = counts.clone();
    let schema2 = schema.clone();
    dep.sim.spawn(async move {
        let n = 6u32;
        let ids: Vec<Key> = (0..n).map(|i| field_id(1 + i / 4, i % 4)).collect();
        let loc = fdbr::fdb::FieldLocation::Null { length: 512 };
        grouped.begin_archive_group();
        for id in &ids {
            let (ds, colloc, elem) = schema2.split(id).unwrap();
            grouped.archive(&ds, &colloc, &elem, &loc).await.unwrap();
        }
        grouped.end_archive_group().await.unwrap();
        for id in &ids {
            let (ds, colloc, elem) = schema2.split(id).unwrap();
            bare.archive(&ds, &colloc, &elem, &loc).await.unwrap();
        }
        *counts2.borrow_mut() = (grouped.wal_sync_count(), bare.wal_sync_count());
    });
    dep.sim.run();
    let (grouped_syncs, bare_syncs) = *counts.borrow();
    assert_eq!(grouped_syncs, 1, "group commit: one barrier per batch");
    assert_eq!(bare_syncs, 6, "bare durable archives: one barrier each");
}

#[test]
fn group_committed_batch_recovers_after_a_crash() {
    // end-to-end: a durable writer archives one engine batch at depth 4
    // (store pass fanned out, catalogue pass group-committed) and dies
    // without flush or close. The group barrier ran inside
    // `archive_many`, so every intent is on disk: recovery must replay
    // all of them and every field must read back byte-identical.
    let field = 8u64 << 10;
    let n = 12usize;
    let dep = deploy(Testbed::Gcp, SystemKind::Lustre, 2, 2, RedundancyOpt::None)
        .with_io(IoProfile::depth(4).with_durable(true));
    let nodes = dep.client_nodes();
    let ids: Vec<Key> = (0..n as u32).map(|i| field_id(1 + i / 4, i % 4)).collect();
    let mut w = dep.fdb(&nodes[0]);
    {
        let ids = ids.clone();
        dep.sim.spawn(async move {
            let batch: Vec<(Key, Bytes)> = ids
                .iter()
                .enumerate()
                .map(|(i, id)| (id.clone(), Bytes::virt(field, i as u64)))
                .collect();
            w.archive_many(batch).await.unwrap();
            drop(w); // crash: no flush, no close — only the WAL survives
        });
        dep.sim.run();
    }
    let mut rec = dep.fdb(&nodes[1]);
    let ds = ids[0].project(&rec.schema.dataset.clone()).unwrap();
    let outcome = Rc::new(RefCell::new((0usize, 0usize)));
    let outcome2 = outcome.clone();
    {
        let ids = ids.clone();
        dep.sim.spawn(async move {
            let stats = rec.recover(&ds).await.expect("recover");
            rec.flush().await.expect("publish recovered index");
            rec.close().await.expect("close");
            rec.invalidate_preload(&ds);
            let found = rec.retrieve_many(&ids).await.expect("retrieve_many");
            let mut verified = 0usize;
            for (i, (id, bytes)) in found.iter().enumerate() {
                assert_eq!(id, &ids[i]);
                if bytes.content_eq(&Bytes::virt(field, i as u64)) {
                    verified += 1;
                }
            }
            *outcome2.borrow_mut() = (stats.replayed, verified);
        });
        dep.sim.run();
    }
    let (replayed, verified) = *outcome.borrow();
    assert_eq!(replayed, n, "every group-committed intent replays");
    assert_eq!(verified, n, "every recovered field reads back byte-identical");
}
