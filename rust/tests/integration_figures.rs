//! Shape assertions on the paper figures: who wins, roughly by how much,
//! and where crossovers fall (the reproduction's acceptance criteria —
//! recorded against the thesis in EXPERIMENTS.md). Run at reduced op
//! scale; aggregate bandwidths are steady-state.

use fdbr::bench::figures::run_figure;

const SCALE: f64 = 0.02;

#[test]
fn fig4_7_ior_daos_scales_and_leads() {
    let f = run_figure("fig4_7", SCALE).unwrap();
    // DAOS write scales close to linearly with servers
    let d2 = f.value("2 servers", "DAOS write").unwrap();
    let d8 = f.value("8 servers", "DAOS write").unwrap();
    assert!(d8 > 2.5 * d2, "DAOS write scaling {d2} -> {d8}");
    // At the largest point both systems push the NIC roofline for
    // IOR's large sequential reads (thesis: they are close for generic
    // bulk I/O; the FDB workloads are where DAOS pulls ahead).
    let dr = f.value("8 servers", "DAOS read").unwrap();
    let lr = f.value("8 servers", "Lustre read").unwrap();
    assert!(
        dr > 0.8 * lr,
        "DAOS read {dr} vs Lustre {lr} at 8 servers"
    );
}

#[test]
fn fig4_12_hammer_daos_ahead_at_scale() {
    let f = run_figure("fig4_12", SCALE).unwrap();
    let dr = f.value("8 servers", "DAOS read").unwrap();
    let lr = f.value("8 servers", "Lustre read").unwrap();
    assert!(dr > lr, "hammer read: DAOS {dr} vs Lustre {lr}");
    let dw = f.value("8 servers", "DAOS write").unwrap();
    assert!(dw > 10.0, "DAOS hammer write should reach tens of GiB/s: {dw}");
}

#[test]
fn fig4_21_gcp_three_way_ordering() {
    let f = run_figure("fig4_21", SCALE).unwrap();
    // thesis: DAOS ≥ Lustre > Ceph for writes on GCP
    let dw = f.series_mean("DAOS write");
    let cw = f.series_mean("Ceph write");
    assert!(dw > cw, "DAOS write {dw} vs Ceph {cw}");
    let dr = f.series_mean("DAOS read");
    let cr = f.series_mean("Ceph read");
    assert!(dr > cr, "DAOS read {dr} vs Ceph {cr}");
}

#[test]
fn fig4_26_small_objects_daos_leads_object_stores() {
    let f = run_figure("fig4_26", SCALE).unwrap();
    let dw = f.value("1KiB objects", "DAOS write").unwrap();
    let cw = f.value("1KiB objects", "Ceph write").unwrap();
    assert!(dw > cw, "1KiB write: DAOS {dw} vs Ceph {cw} MiB/s");
    let dr = f.value("1KiB objects", "DAOS read").unwrap();
    let lr = f.value("1KiB objects", "Lustre read").unwrap();
    assert!(dr > 2.0 * lr, "1KiB read: DAOS {dr} vs Lustre {lr} MiB/s");
}

#[test]
fn fig4_27_replication_costs_writes() {
    let base = run_figure("fig4_21", SCALE).unwrap();
    let repl = run_figure("fig4_27", SCALE).unwrap();
    // replication must cost Ceph write bandwidth vs its unreplicated run
    let b = base.value("4 servers", "Ceph write").unwrap();
    let r = repl.value("4 servers", "Ceph write").unwrap();
    assert!(
        r < 0.8 * b,
        "RF=2 Ceph write {r} should be well below unreplicated {b}"
    );
    // DAOS stays ahead of Ceph under replication
    let dr = repl.value("4 servers", "DAOS write").unwrap();
    assert!(dr > r, "replicated DAOS write {dr} vs Ceph {r}");
}

#[test]
fn fig4_30_dummy_libdaos_shows_client_overhead_is_small() {
    let f = run_figure("fig4_30", SCALE).unwrap();
    let real = f.value("4-VM deployment", "DAOS write").unwrap();
    let dummy = f.value("4-VM deployment", "dummy libdaos write").unwrap();
    assert!(
        dummy > 5.0 * real,
        "dummy {dummy} should dwarf real {real}: client library is not the bottleneck"
    );
}

#[test]
fn fig3_5_ceph_config_sweep_shapes() {
    let f = run_figure("fig3_5", SCALE).unwrap();
    let w_objper = f.value("ns+obj-per-field", "write").unwrap();
    let w_single = f.value("ns+single-large", "write").unwrap();
    let r_objper = f.value("ns+obj-per-field", "read").unwrap();
    let r_single = f.value("ns+single-large", "read").unwrap();
    // single-large: best read, but write clearly below obj-per-field
    assert!(w_objper > w_single, "obj-per-field write {w_objper} vs single {w_single}");
    assert!(r_single >= 0.9 * r_objper, "single-large read {r_single} vs {r_objper}");
    // the async config exists and is flagged inconsistent
    assert!(f
        .rows
        .iter()
        .any(|r| r.series.contains("INCONSISTENT")));
}

#[test]
fn profile_figures_show_expected_classes() {
    let lustre = run_figure("fig4_25", SCALE).unwrap();
    let daos = run_figure("fig4_23", SCALE).unwrap();
    // Lustre contention profile includes lock time; DAOS never does
    let lustre_contended = &lustre.profiles[1].1;
    assert!(
        lustre_contended.contains("lock"),
        "lustre contended profile should show lock time: {lustre_contended}"
    );
    for (_, p) in &daos.profiles {
        assert!(!p.contains("lock"), "DAOS profile must have no lock class: {p}");
    }
}

#[test]
fn fig4_29_dfs_competitive() {
    let f = run_figure("fig4_29", SCALE).unwrap();
    let d = f.value("16-VM-equivalent", "DAOS/DFS write").unwrap();
    let l = f.value("16-VM-equivalent", "Lustre write").unwrap();
    assert!(d > 0.5 * l, "DAOS/DFS write {d} vs Lustre {l}");
}
