"""pytest path shim: lets `pytest python/tests/` work from the repo root
(the `compile` package lives beside this file)."""

import os
import sys

sys.path.insert(0, os.path.dirname(os.path.abspath(__file__)))
