"""AOT export: lower the L2 graphs to HLO **text** for the Rust runtime.

HLO text (not ``HloModuleProto.serialize``) is the interchange format —
jax ≥ 0.5 emits protos with 64-bit instruction ids that the image's
xla_extension 0.5.1 rejects; the text parser reassigns ids (see
/opt/xla-example/README.md).

Usage: ``python -m compile.aot --outdir ../artifacts``
Emits, for each grid size G in GRIDS and ensemble size E:

* ``pgen_e{E}_g{G}.hlo.txt``   — pgen_products([E,G,G], thr)
* ``model_step_g{G}.hlo.txt``  — model_step([G,G], [G,G])
* ``codec_g{G}.hlo.txt``       — codec_roundtrip([G,G])
* ``manifest.json``            — shapes/entry metadata for the loader
"""

import argparse
import json
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model

GRIDS = (32, 64)
ENSEMBLE = 8


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def export(outdir: str) -> dict:
    os.makedirs(outdir, exist_ok=True)
    manifest = {"ensemble": ENSEMBLE, "grids": list(GRIDS), "artifacts": {}}

    def emit(name, fn, *args):
        lowered = jax.jit(fn).lower(*args)
        text = to_hlo_text(lowered)
        path = os.path.join(outdir, f"{name}.hlo.txt")
        with open(path, "w") as f:
            f.write(text)
        manifest["artifacts"][name] = {
            "inputs": [list(a.shape) for a in args],
            "bytes": len(text),
        }
        print(f"wrote {path} ({len(text)} chars)")

    for g in GRIDS:
        field = jax.ShapeDtypeStruct((g, g), jnp.float32)
        ens = jax.ShapeDtypeStruct((ENSEMBLE, g, g), jnp.float32)
        thr = jax.ShapeDtypeStruct((), jnp.float32)
        emit(f"pgen_e{ENSEMBLE}_g{g}", model.pgen_products, ens, thr)
        emit(f"model_step_g{g}", model.model_step, field, field)
        emit(f"codec_g{g}", model.codec_roundtrip, field)

    with open(os.path.join(outdir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=2)
    return manifest


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--outdir", default="../artifacts")
    ap.add_argument("--out", default=None, help="compat: marker file path")
    args = ap.parse_args()
    outdir = args.outdir
    if args.out:
        outdir = os.path.dirname(args.out) or "."
    export(outdir)
    if args.out:
        # marker for the Makefile dependency
        with open(args.out, "w") as f:
            f.write("see manifest.json\n")


if __name__ == "__main__":
    main()
