"""L2: the JAX compute graphs AOT-compiled for the Rust coordinator.

Three graphs, all calling the L1 Pallas kernels:

* ``pgen_products`` — PGEN's derived-product generation: decode the
  ensemble's quantized fields (codec path exercised end-to-end), fused
  ensemble statistics, re-quantize the products for archival.
* ``model_step`` — the synthetic NWP model: damped diffusion +
  stochastic forcing, producing the next step's field.
* ``codec_roundtrip`` — the store-side compression path alone.

Python runs only at build time (``make artifacts``); the lowered HLO
text is executed by ``rust/src/runtime`` via PJRT.
"""

import jax.numpy as jnp

from .kernels import ensemble, pack, stencil


def pgen_products(ens, threshold):
    """``[E, H, W] f32`` ensemble → stacked products ``[3, H, W]``:
    mean, spread, exceedance probability — each roundtripped through the
    16-bit codec exactly as they would be archived."""
    mean, spread, prob = ensemble.ensemble_stats(ens, threshold)
    mean_c = pack.codec_roundtrip(mean)
    spread_c = pack.codec_roundtrip(spread)
    # probabilities are archived unpacked (tiny dynamic range)
    return jnp.stack([mean_c, spread_c, prob], axis=0)


def model_step(state, noise):
    """One synthetic model step: two diffusion sweeps, damping toward
    climatology, stochastic forcing. ``[H, W] f32 × 2 → [H, W] f32``."""
    x = stencil.diffuse(state)
    x = stencil.diffuse(x)
    return 0.98 * x + 0.3 * noise


def codec_roundtrip(field):
    """Quantize + dequantize one field (the Store compression path)."""
    return pack.codec_roundtrip(field)
