"""L1 Pallas kernel: GRIB-style simple packing (16-bit quantization).

TPU adaptation (DESIGN.md §Hardware-Adaptation): the field is blocked
``(BLOCK, BLOCK)`` so each tile fits VMEM; the min/max reduction is a
separate jnp pass (XLA fuses it), and the quantize/dequantize maps run
as Pallas grids over tiles with ``BlockSpec`` expressing the HBM↔VMEM
schedule. ``interpret=True`` everywhere — the CPU PJRT plugin cannot run
Mosaic custom-calls (see /opt/xla-example/README.md).
"""

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

BLOCK = 64


def _quantize_kernel(x_ref, lo_ref, scale_ref, q_ref):
    lo = lo_ref[0]
    scale = scale_ref[0]
    x = x_ref[...]
    q = jnp.clip(jnp.round((x - lo) / scale), 0.0, 65535.0)
    q_ref[...] = q.astype(jnp.int32)


def _dequantize_kernel(q_ref, lo_ref, scale_ref, x_ref):
    lo = lo_ref[0]
    scale = scale_ref[0]
    x_ref[...] = lo + scale * q_ref[...].astype(jnp.float32)


def _grid_specs(shape):
    h, w = shape
    bh = min(BLOCK, h)
    bw = min(BLOCK, w)
    grid = (pl.cdiv(h, bh), pl.cdiv(w, bw))
    tile = pl.BlockSpec((bh, bw), lambda i, j: (i, j))
    scalar = pl.BlockSpec((1,), lambda i, j: (0,))
    return grid, tile, scalar


def quantize(field):
    """``[H, W] f32`` → ``(q i32, lo f32, scale f32)`` via a Pallas map."""
    lo = jnp.min(field)
    hi = jnp.max(field)
    span = jnp.maximum(hi - lo, jnp.finfo(jnp.float32).tiny)
    scale = span / 65535.0
    grid, tile, scalar = _grid_specs(field.shape)
    q = pl.pallas_call(
        _quantize_kernel,
        grid=grid,
        in_specs=[tile, scalar, scalar],
        out_specs=tile,
        out_shape=jax.ShapeDtypeStruct(field.shape, jnp.int32),
        interpret=True,
    )(field, lo[None], scale[None])
    return q, lo, scale


def dequantize(q, lo, scale):
    """Inverse Pallas map of :func:`quantize`."""
    grid, tile, scalar = _grid_specs(q.shape)
    return pl.pallas_call(
        _dequantize_kernel,
        grid=grid,
        in_specs=[tile, scalar, scalar],
        out_specs=tile,
        out_shape=jax.ShapeDtypeStruct(q.shape, jnp.float32),
        interpret=True,
    )(q, lo[None], scale[None])


def codec_roundtrip(field):
    """quantize → dequantize: the store-side compression path whose
    error bound tests assert GRIB-packing semantics."""
    q, lo, scale = quantize(field)
    return dequantize(q, lo, scale)
