"""Pure-jnp oracles for the Pallas kernels — the correctness anchors.

Every kernel in this package must match its oracle to float tolerance
under pytest (and hypothesis shape/value sweeps). The oracles are also
the semantic reference mirrored by the Rust-side implementations in
``rust/src/workflow/fields.rs``.
"""

import jax.numpy as jnp


def quantize_ref(field):
    """GRIB simple packing (16-bit): returns (q_u16_as_i32, lo, scale)."""
    lo = jnp.min(field)
    hi = jnp.max(field)
    span = jnp.maximum(hi - lo, jnp.finfo(jnp.float32).tiny)
    scale = span / 65535.0
    q = jnp.clip(jnp.round((field - lo) / scale), 0, 65535).astype(jnp.int32)
    return q, lo, scale


def dequantize_ref(q, lo, scale):
    """Inverse of :func:`quantize_ref`."""
    return lo + scale * q.astype(jnp.float32)


def ensemble_stats_ref(ens, threshold):
    """Ensemble statistics over the member axis (axis 0) of ``[E, H, W]``.

    Returns (mean, spread, exceedance probability) each ``[H, W]``.
    """
    mean = jnp.mean(ens, axis=0)
    spread = jnp.std(ens, axis=0)
    prob = jnp.mean((ens > threshold).astype(jnp.float32), axis=0)
    return mean, spread, prob


def diffuse_ref(field):
    """One 5-point diffusion sweep with edge clamping (the model step's
    stencil): ``out = 0.5*c + 0.125*(up + down + left + right)``."""
    up = jnp.roll(field, 1, axis=0).at[0, :].set(field[0, :])
    dn = jnp.roll(field, -1, axis=0).at[-1, :].set(field[-1, :])
    lf = jnp.roll(field, 1, axis=1).at[:, 0].set(field[:, 0])
    rt = jnp.roll(field, -1, axis=1).at[:, -1].set(field[:, -1])
    return 0.5 * field + 0.125 * (up + dn + lf + rt)
