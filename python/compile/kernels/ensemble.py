"""L1 Pallas kernel: fused ensemble statistics (PGEN's hot spot).

For each spatial tile the full member axis is VMEM-resident, so mean,
spread, and exceedance probability reduce over members without
re-fetching the tile from HBM — the fusion a naive per-statistic jnp
graph would lose. Grid: spatial tiles; member axis innermost (see
DESIGN.md §Hardware-Adaptation).
"""

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

BLOCK = 64


def _stats_kernel(ens_ref, thr_ref, mean_ref, spread_ref, prob_ref):
    ens = ens_ref[...]  # [E, bh, bw] — whole member axis in VMEM
    thr = thr_ref[0]
    e = ens.shape[0]
    mean = jnp.sum(ens, axis=0) / e
    var = jnp.sum((ens - mean[None, :, :]) ** 2, axis=0) / e
    mean_ref[...] = mean
    spread_ref[...] = jnp.sqrt(var)
    prob_ref[...] = jnp.sum((ens > thr).astype(jnp.float32), axis=0) / e


def ensemble_stats(ens, threshold):
    """``[E, H, W] f32`` → (mean, spread, prob) each ``[H, W]``."""
    e, h, w = ens.shape
    bh = min(BLOCK, h)
    bw = min(BLOCK, w)
    grid = (pl.cdiv(h, bh), pl.cdiv(w, bw))
    ens_spec = pl.BlockSpec((e, bh, bw), lambda i, j: (0, i, j))
    out_spec = pl.BlockSpec((bh, bw), lambda i, j: (i, j))
    scalar = pl.BlockSpec((1,), lambda i, j: (0,))
    out_shape = jax.ShapeDtypeStruct((h, w), jnp.float32)
    return pl.pallas_call(
        _stats_kernel,
        grid=grid,
        in_specs=[ens_spec, scalar],
        out_specs=(out_spec, out_spec, out_spec),
        out_shape=(out_shape, out_shape, out_shape),
        interpret=True,
    )(ens, jnp.asarray(threshold, jnp.float32)[None])
