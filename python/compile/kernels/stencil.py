"""L1 Pallas kernel: 5-point diffusion stencil (the synthetic model's
dynamical core). The whole (small) grid is one VMEM block — NWP grids in
this reproduction are ≤ 256², i.e. ≤ 256 KiB f32, comfortably inside
VMEM; larger grids would tile with halo exchange via index_map overlap.
"""

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _diffuse_kernel(x_ref, o_ref):
    x = x_ref[...]
    # edge-clamped neighbors (jnp.roll + boundary fix, vectorized)
    up = jnp.concatenate([x[:1, :], x[:-1, :]], axis=0)
    dn = jnp.concatenate([x[1:, :], x[-1:, :]], axis=0)
    lf = jnp.concatenate([x[:, :1], x[:, :-1]], axis=1)
    rt = jnp.concatenate([x[:, 1:], x[:, -1:]], axis=1)
    o_ref[...] = 0.5 * x + 0.125 * (up + dn + lf + rt)


def diffuse(field):
    """One edge-clamped 5-point diffusion sweep, ``[H, W] f32``."""
    return pl.pallas_call(
        _diffuse_kernel,
        out_shape=jax.ShapeDtypeStruct(field.shape, jnp.float32),
        interpret=True,
    )(field)
