"""L2 graph tests: shapes, composition, and AOT exportability."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import aot, model
from compile.kernels import ref


def ensemble_fields(e=8, g=32, seed=0):
    rng = np.random.default_rng(seed)
    return jnp.asarray(rng.uniform(-10, 30, size=(e, g, g)).astype(np.float32))


class TestPgenProducts:
    def test_shapes(self):
        ens = ensemble_fields()
        out = model.pgen_products(ens, 15.0)
        assert out.shape == (3, 32, 32)
        assert out.dtype == jnp.float32

    def test_products_near_reference(self):
        ens = ensemble_fields(seed=3)
        out = model.pgen_products(ens, 15.0)
        mean_r, spread_r, prob_r = ref.ensemble_stats_ref(ens, 15.0)
        # mean/spread pass through the 16-bit codec: tolerance = span/65535
        span_m = float(jnp.max(mean_r) - jnp.min(mean_r))
        np.testing.assert_allclose(
            out[0], mean_r, atol=span_m / 65535.0 + 1e-4
        )
        span_s = float(jnp.max(spread_r) - jnp.min(spread_r))
        np.testing.assert_allclose(
            out[1], spread_r, atol=span_s / 65535.0 + 1e-4
        )
        np.testing.assert_allclose(out[2], prob_r, atol=1e-6)

    def test_jittable(self):
        ens = ensemble_fields()
        jitted = jax.jit(model.pgen_products)
        out = jitted(ens, jnp.float32(15.0))
        assert out.shape == (3, 32, 32)


class TestModelStep:
    def test_damps_and_forces(self):
        g = 32
        state = jnp.full((g, g), 10.0, jnp.float32)
        zero = jnp.zeros((g, g), jnp.float32)
        out = model.model_step(state, zero)
        # constant field: diffusion preserves, damping scales by 0.98
        np.testing.assert_allclose(out, 0.98 * state, rtol=1e-5)
        forced = model.model_step(state, jnp.ones((g, g), jnp.float32))
        np.testing.assert_allclose(forced, 0.98 * state + 0.3, rtol=1e-5)

    def test_stability_over_steps(self):
        g = 32
        rng = np.random.default_rng(1)
        state = jnp.asarray(rng.normal(0, 10, (g, g)).astype(np.float32))
        for i in range(20):
            noise = jnp.asarray(
                rng.normal(0, 1, (g, g)).astype(np.float32)
            )
            state = model.model_step(state, noise)
        assert bool(jnp.all(jnp.isfinite(state)))
        assert float(jnp.max(jnp.abs(state))) < 100.0


class TestAotExport:
    def test_export_produces_parseable_hlo(self, tmp_path):
        manifest = aot.export(str(tmp_path))
        assert set(manifest["artifacts"]) == {
            f"{k}_g{g}"
            for g in (32, 64)
            for k in ("pgen_e8", "model_step", "codec")
        }
        for name in manifest["artifacts"]:
            text = (tmp_path / f"{name}.hlo.txt").read_text()
            assert text.startswith("HloModule"), name
            assert "ENTRY" in text, name

    def test_codec_artifact_numerics(self, tmp_path):
        # lower codec, re-execute via jax from the lowered function to
        # confirm the exported computation is the same graph
        f = ensemble_fields(e=1, g=32)[0]
        direct = model.codec_roundtrip(f)
        jitted = jax.jit(model.codec_roundtrip)(f)
        np.testing.assert_allclose(direct, jitted, rtol=1e-6)
