"""Hypothesis sweeps: kernel/oracle agreement over random shapes and
value distributions (the property layer on top of test_kernels.py).

Skips cleanly when hypothesis is not installed (offline containers);
test_kernels.py still covers the deterministic cases."""

import jax.numpy as jnp
import numpy as np
import pytest

hypothesis = pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from compile.kernels import ensemble, pack, ref, stencil

dims = st.integers(min_value=2, max_value=96)
small_dims = st.integers(min_value=2, max_value=48)
members = st.integers(min_value=1, max_value=8)
seeds = st.integers(min_value=0, max_value=2**31 - 1)
spans = st.floats(min_value=1e-3, max_value=1e6, allow_nan=False)


def field_from(h, w, seed, span):
    rng = np.random.default_rng(seed)
    return jnp.asarray(
        rng.uniform(-span, span, size=(h, w)).astype(np.float32)
    )


@settings(max_examples=25, deadline=None)
@given(h=dims, w=dims, seed=seeds, span=spans)
def test_quantize_always_matches_ref(h, w, seed, span):
    f = field_from(h, w, seed, span)
    q, lo, scale = pack.quantize(f)
    q_r, lo_r, scale_r = ref.quantize_ref(f)
    assert float(lo) == float(lo_r)
    np.testing.assert_allclose(scale, scale_r, rtol=1e-6)
    assert int(jnp.max(jnp.abs(q - q_r))) <= 1


@settings(max_examples=25, deadline=None)
@given(h=dims, w=dims, seed=seeds, span=spans)
def test_codec_roundtrip_error_bounded(h, w, seed, span):
    f = field_from(h, w, seed, span)
    back = pack.codec_roundtrip(f)
    value_span = float(jnp.max(f) - jnp.min(f))
    bound = max(value_span, 1e-6) / 65535.0 * 0.51 + 1e-5 + value_span * 1e-6
    assert float(jnp.max(jnp.abs(back - f))) <= bound


@settings(max_examples=20, deadline=None)
@given(e=members, h=small_dims, w=small_dims, seed=seeds, thr=st.floats(-50, 50))
def test_ensemble_stats_match_ref(e, h, w, seed, thr):
    rng = np.random.default_rng(seed)
    ens = jnp.asarray(rng.normal(0, 10, size=(e, h, w)).astype(np.float32))
    mean, spread, prob = ensemble.ensemble_stats(ens, thr)
    mean_r, spread_r, prob_r = ref.ensemble_stats_ref(ens, thr)
    np.testing.assert_allclose(mean, mean_r, rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(spread, spread_r, rtol=1e-3, atol=1e-3)
    np.testing.assert_allclose(prob, prob_r, atol=1e-6)


@settings(max_examples=20, deadline=None)
@given(h=dims, w=dims, seed=seeds)
def test_stencil_matches_ref_and_bounds(h, w, seed):
    f = field_from(h, w, seed, 100.0)
    out = stencil.diffuse(f)
    np.testing.assert_allclose(out, ref.diffuse_ref(f), rtol=1e-5, atol=1e-4)
    # diffusion cannot exceed input extremes
    assert float(jnp.max(out)) <= float(jnp.max(f)) + 1e-3
    assert float(jnp.min(out)) >= float(jnp.min(f)) - 1e-3
