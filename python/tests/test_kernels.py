"""Kernel-vs-oracle correctness: the CORE L1 signal (pytest)."""

import jax.numpy as jnp
import numpy as np
import pytest

from compile.kernels import ensemble, pack, ref, stencil


def smooth_field(h, w, seed):
    rng = np.random.default_rng(seed)
    f = rng.uniform(-10.0, 30.0, size=(h, w)).astype(np.float32)
    # crude smoothing for realistic dynamic range
    f = 0.25 * (np.roll(f, 1, 0) + np.roll(f, -1, 0) + np.roll(f, 1, 1) + np.roll(f, -1, 1))
    return jnp.asarray(f)


class TestQuantize:
    @pytest.mark.parametrize("shape", [(32, 32), (64, 64), (64, 128), (100, 60)])
    def test_matches_ref(self, shape):
        f = smooth_field(*shape, seed=1)
        q, lo, scale = pack.quantize(f)
        q_r, lo_r, scale_r = ref.quantize_ref(f)
        np.testing.assert_allclose(lo, lo_r, rtol=1e-6)
        np.testing.assert_allclose(scale, scale_r, rtol=1e-6)
        # quantization may differ by 1 ulp at rounding boundaries
        assert int(jnp.max(jnp.abs(q - q_r))) <= 1

    def test_q_range(self):
        f = smooth_field(64, 64, seed=2)
        q, _, _ = pack.quantize(f)
        assert int(jnp.min(q)) >= 0
        assert int(jnp.max(q)) <= 65535

    def test_roundtrip_error_bound(self):
        f = smooth_field(64, 64, seed=3)
        back = pack.codec_roundtrip(f)
        span = float(jnp.max(f) - jnp.min(f))
        bound = span / 65535.0 * 0.51 + 1e-5
        assert float(jnp.max(jnp.abs(back - f))) <= bound

    def test_constant_field(self):
        f = jnp.full((32, 32), 5.0, jnp.float32)
        back = pack.codec_roundtrip(f)
        np.testing.assert_allclose(back, f, atol=1e-3)

    def test_dequantize_matches_ref(self):
        f = smooth_field(64, 64, seed=4)
        q, lo, scale = ref.quantize_ref(f)
        a = pack.dequantize(q, lo, scale)
        b = ref.dequantize_ref(q, lo, scale)
        np.testing.assert_allclose(a, b, rtol=1e-4, atol=1e-5)


class TestEnsembleStats:
    @pytest.mark.parametrize("e,h,w", [(4, 32, 32), (8, 64, 64), (8, 64, 128), (3, 100, 52)])
    def test_matches_ref(self, e, h, w):
        ens = jnp.stack([smooth_field(h, w, seed=i) for i in range(e)])
        thr = 10.0
        mean, spread, prob = ensemble.ensemble_stats(ens, thr)
        mean_r, spread_r, prob_r = ref.ensemble_stats_ref(ens, thr)
        np.testing.assert_allclose(mean, mean_r, rtol=1e-5, atol=1e-5)
        np.testing.assert_allclose(spread, spread_r, rtol=1e-4, atol=1e-4)
        np.testing.assert_allclose(prob, prob_r, rtol=1e-6, atol=1e-6)

    def test_prob_bounds(self):
        ens = jnp.stack([smooth_field(32, 32, seed=i) for i in range(5)])
        _, _, prob = ensemble.ensemble_stats(ens, 0.0)
        assert float(jnp.min(prob)) >= 0.0
        assert float(jnp.max(prob)) <= 1.0

    def test_identical_members_zero_spread(self):
        f = smooth_field(32, 32, seed=9)
        ens = jnp.stack([f] * 6)
        mean, spread, _ = ensemble.ensemble_stats(ens, 0.0)
        np.testing.assert_allclose(mean, f, rtol=1e-6)
        np.testing.assert_allclose(spread, jnp.zeros_like(f), atol=1e-3)


class TestStencil:
    @pytest.mark.parametrize("shape", [(16, 16), (64, 64), (33, 65)])
    def test_matches_ref(self, shape):
        f = smooth_field(*shape, seed=5)
        a = stencil.diffuse(f)
        b = ref.diffuse_ref(f)
        np.testing.assert_allclose(a, b, rtol=1e-6, atol=1e-6)

    def test_conserves_constant(self):
        f = jnp.full((32, 32), 7.0, jnp.float32)
        out = stencil.diffuse(f)
        np.testing.assert_allclose(out, f, rtol=1e-6)

    def test_smooths_extremes(self):
        f = jnp.zeros((16, 16), jnp.float32).at[8, 8].set(100.0)
        out = stencil.diffuse(f)
        assert float(out[8, 8]) < 100.0
        assert float(out[8, 9]) > 0.0
