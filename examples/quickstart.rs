//! Quickstart: archive and retrieve weather fields through the FDB on a
//! simulated DAOS cluster — the 60-second tour of the public API.
//!
//! Run: `cargo run --release --example quickstart`

use fdbr::bench::scenario::{deploy, RedundancyOpt, SystemKind};
use fdbr::fdb::{BackendConfig, FdbBuilder, Key, Request};
use fdbr::hw::profiles::Testbed;

fn main() {
    // 1. Deploy a simulated testbed: 2 DAOS server nodes, 2 client nodes.
    let dep = deploy(Testbed::Gcp, SystemKind::Daos, 2, 2, RedundancyOpt::None);
    let writer_node = dep.client_nodes()[0].clone();
    let reader_node = dep.client_nodes()[1].clone();

    // 2. One FDB instance per process (like linking libfdb), built
    //    declaratively: the BackendConfig names the backend pair + knobs.
    let fdbr::bench::scenario::SystemUnderTest::Daos(daos) = &dep.system else {
        unreachable!()
    };
    let config = || BackendConfig::Daos {
        daos: daos.clone(),
        pool: "fdb".to_string(),
        hash_oids: false,
    };
    let mut writer = FdbBuilder::new(&dep.sim)
        .node(&writer_node)
        .backend(config())
        .build()
        .expect("valid config");
    let mut reader = FdbBuilder::new(&dep.sim)
        .node(&reader_node)
        .backend(config())
        .build()
        .expect("valid config");

    // 3. Archive a few fields, then retrieve them from another process.
    dep.sim.spawn(async move {
        for step in 1..=3u32 {
            let id = Key::parse(
                "class=od,expver=0001,stream=oper,date=20231201,time=1200,\
                 type=fc,levtype=sfc,number=1,levelist=1,param=2t",
            )
            .unwrap()
            .with("step", step.to_string());
            let payload = format!("field bytes for step {step}");
            writer.archive(&id, payload.as_bytes()).await.unwrap();
            println!("archived  {id}");
        }
        writer.flush().await.expect("flush"); // no-op on DAOS: already durable + visible

        // multi-step request with a wildcard, expanded from the axes
        let mut req = Request::parse(
            "class=od,expver=0001,stream=oper,date=20231201,time=1200,\
             type=fc,levtype=sfc,number=1,levelist=1,param=2t,step=*",
        )
        .unwrap();
        req.bind("step", vec![]); // `*` → wildcard
        let handles = reader.retrieve_request(&req).await.unwrap();
        for h in &handles {
            let bytes = reader.read(h).await.unwrap().to_vec();
            println!(
                "retrieved {} bytes: {:?}...",
                bytes.len(),
                String::from_utf8_lossy(&bytes[..bytes.len().min(28)])
            );
        }
        assert_eq!(handles.iter().map(|h| h.io_ops()).sum::<usize>(), 3);
    });
    let end = dep.sim.run();
    println!("done in {end} of simulated time");
}
