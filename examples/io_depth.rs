//! The I/O-depth engine quickstart: drive N concurrent store reads and
//! writes on the batched FDB paths via per-request client sessions, and
//! watch the retrieve phase's virtual time fall as the queue deepens —
//! results stay byte-identical at every depth.
//!
//! Run: `cargo run --release --example io_depth`

use fdbr::bench::hammer::{field_id, field_seed};
use fdbr::bench::scenario::{deploy, RedundancyOpt, SystemKind};
use fdbr::fdb::{IoProfile, Key};
use fdbr::hw::profiles::Testbed;
use fdbr::util::content::Bytes;

const FIELD: u64 = 64 << 10;

fn ids() -> Vec<Key> {
    let mut out = Vec::new();
    for step in 1..=4u32 {
        for param in 0..4 {
            for level in 0..4 {
                out.push(field_id(0, step, param, level));
            }
        }
    }
    out
}

fn main() {
    println!("== queue-depth I/O engine (per-backend client sessions) ==");
    let mut baseline = None;
    for depth in [1usize, 2, 4, 8, 16] {
        // index caching rides along so the serial catalogue client does
        // not mask the store-side parallelism we are sweeping
        let io = IoProfile::depth(depth).with_preload_indexes(true);
        let dep = deploy(Testbed::Gcp, SystemKind::Lustre, 2, 2, RedundancyOpt::None)
            .with_io(io);
        let nodes = dep.client_nodes();
        let mut writer = dep.fdb(&nodes[0]);
        let mut reader = dep.fdb(&nodes[1]);
        let (t_read, fingerprint) = {
            use std::cell::Cell;
            use std::rc::Rc;
            let out: Rc<Cell<(f64, u64)>> = Rc::new(Cell::new((0.0, 0)));
            let out2 = out.clone();
            let sim = dep.sim.clone();
            dep.sim.spawn(async move {
                let batch: Vec<(Key, Bytes)> = ids()
                    .into_iter()
                    .map(|id| {
                        let data = Bytes::virt(FIELD, field_seed(&id));
                        (id, data)
                    })
                    .collect();
                // archive_many fans the store pass out over `depth`
                // client sessions; flush covers every session's files
                writer.archive_many(batch).await.unwrap();
                writer.flush().await.unwrap();
                writer.close().await.expect("close");

                let t0 = sim.now();
                let fetched = reader.retrieve_many(&ids()).await.unwrap();
                let dt = (sim.now() - t0).as_secs_f64() * 1e3;
                // order + content fingerprint: identical at every depth
                assert_eq!(fetched.len(), ids().len());
                let mut fp: u64 = 0;
                for (id, bytes) in &fetched {
                    assert!(bytes.content_eq(&Bytes::virt(FIELD, field_seed(id))));
                    fp = fp
                        .wrapping_mul(1099511628211)
                        .wrapping_add(bytes.len() ^ field_seed(id));
                }
                assert!(reader.io_inflight_peak() <= depth);
                out2.set((dt, fp));
            });
            dep.sim.run();
            out.get()
        };
        let speedup = baseline.map(|b: f64| b / t_read).unwrap_or(1.0);
        if baseline.is_none() {
            baseline = Some(t_read);
        }
        println!(
            "  io-depth {depth:>2}: retrieve phase {t_read:8.2} ms  \
             ({speedup:4.1}x vs depth 1, fingerprint {fingerprint:016x})"
        );
    }
    println!("identical bytes at every depth; only virtual time changed");
}
