//! Small-object performance (thesis Fig 4.26): 1 KiB fields expose the
//! per-op costs — DAOS' user-space path wins big over kernel/TCP paths.
//!
//! Run: `cargo run --release --example small_objects`

use fdbr::bench::hammer::{run, HammerConfig};
use fdbr::bench::scenario::{deploy, RedundancyOpt, SystemKind};
use fdbr::hw::profiles::Testbed;

fn main() {
    println!("1 KiB-object fdb-hammer (8 client procs/node, 4+4 nodes, GCP)");
    println!("{:<8} {:>14} {:>14}", "system", "write MiB/s", "read MiB/s");
    let mut daos = (0.0, 0.0);
    let mut ceph = (0.0, 0.0);
    let mut lustre_read = 0.0;
    for kind in [SystemKind::Lustre, SystemKind::Daos, SystemKind::Ceph] {
        let dep = deploy(Testbed::Gcp, kind, 2, 4, RedundancyOpt::None);
        let (r, _) = run(
            &dep,
            HammerConfig {
                procs_per_node: 8,
                nsteps: 10,
                nparams: 5,
                nlevels: 4,
                field_size: 1 << 10,
                check: false,
                contention: false,
            },
        );
        let w = r.write_bw / (1u64 << 20) as f64;
        let rd = r.read_bw / (1u64 << 20) as f64;
        println!("{:<8} {:>14.1} {:>14.1}", kind.label(), w, rd);
        match kind {
            SystemKind::Daos => daos = (w, rd),
            SystemKind::Ceph => ceph = (w, rd),
            SystemKind::Lustre => lustre_read = rd,
        }
    }
    // Thesis shape (Fig 4.26 / §2.5): DAOS is the only system with high
    // KiB-object performance. Lustre's *apparent* write rate is page-cache
    // buffering (not durable per op) — the honest comparisons are reads,
    // and writes among the immediately-durable object stores.
    assert!(daos.0 > ceph.0, "DAOS durable small writes should beat Ceph");
    assert!(daos.1 > ceph.1, "DAOS small reads should beat Ceph");
    assert!(daos.1 > 2.0 * lustre_read, "DAOS small reads should dwarf Lustre");
    println!("shape check PASSED: DAOS leads KiB-scale durable I/O");
}
