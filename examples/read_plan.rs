//! The vectored read planner quickstart: a dense NWP retrieval — fields
//! archived back-to-back in per-process data files — re-read with read-
//! plan coalescing on and off. With `coalesce_gap` > 0 the batched
//! retrieve merges adjacent fields into a few large ranged I/Os and the
//! virtual retrieve time collapses, while the delivered bytes stay
//! identical to the per-field legacy path.
//!
//! Run: `cargo run --release --example read_plan`

use fdbr::bench::hammer::{field_id, field_seed};
use fdbr::bench::scenario::{deploy, RedundancyOpt, SystemKind};
use fdbr::fdb::{IoProfile, Key};
use fdbr::hw::profiles::Testbed;
use fdbr::util::content::Bytes;

const FIELD: u64 = 64 << 10;
const NFIELDS: usize = 128;

fn ids() -> Vec<Key> {
    // one collocation: every field appends to the same data file
    (0..NFIELDS)
        .map(|i| field_id(0, 1 + (i / 16) as u32, (i % 16) as u32, 0))
        .collect()
}

fn main() {
    println!("== vectored read planner (coalesced ranged I/Os) ==");
    let mut baseline = None;
    for (gap, label) in [
        (0u64, "off (per-field reads)"),
        (4 << 10, "gap   4 KiB"),
        (64 << 10, "gap  64 KiB"),
        (1 << 20, "gap   1 MiB"),
    ] {
        let io = IoProfile::depth(1)
            .with_preload_indexes(true)
            .with_coalesce_gap(gap);
        let dep = deploy(Testbed::Gcp, SystemKind::Lustre, 2, 2, RedundancyOpt::None)
            .with_io(io);
        let nodes = dep.client_nodes();
        let mut writer = dep.fdb(&nodes[0]);
        let mut reader = dep.fdb(&nodes[1]);
        let (t_read, stats, fingerprint) = {
            use std::cell::Cell;
            use std::rc::Rc;
            let out: Rc<Cell<(f64, fdbr::fdb::PlanStats, u64)>> = Rc::new(Cell::new((
                0.0,
                fdbr::fdb::PlanStats::default(),
                0,
            )));
            let out2 = out.clone();
            let sim = dep.sim.clone();
            dep.sim.spawn(async move {
                let batch: Vec<(Key, Bytes)> = ids()
                    .into_iter()
                    .map(|id| {
                        let data = Bytes::virt(FIELD, field_seed(&id));
                        (id, data)
                    })
                    .collect();
                writer.archive_many(batch).await.unwrap();
                writer.flush().await.unwrap();
                writer.close().await.expect("close");

                let t0 = sim.now();
                let fetched = reader.retrieve_many(&ids()).await.unwrap();
                let dt = (sim.now() - t0).as_secs_f64() * 1e3;
                assert_eq!(fetched.len(), NFIELDS);
                // identical bytes at every gap — only the op count moves
                let mut fp: u64 = 0;
                for (id, bytes) in &fetched {
                    assert!(bytes.content_eq(&Bytes::virt(FIELD, field_seed(id))));
                    fp = fp
                        .wrapping_mul(1099511628211)
                        .wrapping_add(bytes.len() ^ field_seed(id));
                }
                out2.set((dt, reader.plan_stats(), fp));
            });
            dep.sim.run();
            out.get()
        };
        let speedup = baseline.map(|b: f64| b / t_read).unwrap_or(1.0);
        if baseline.is_none() {
            baseline = Some(t_read);
        }
        println!(
            "  coalesce {label}: retrieve {t_read:8.2} ms  ({speedup:4.1}x vs off, \
             {} -> {} ops, {} merged, fingerprint {fingerprint:016x})",
            if stats.ops_in > 0 { stats.ops_in } else { NFIELDS as u64 },
            if stats.ops_in > 0 {
                stats.ops_out
            } else {
                NFIELDS as u64
            },
            stats.ops_merged,
        );
    }
    println!("identical bytes at every gap; only the I/O op count changed");
}
