//! End-to-end operational NWP run (the thesis' Fig 2.11 pattern) with
//! REAL PGEN compute: the AOT-compiled JAX/Pallas product-generation
//! graph executes via PJRT for every simulation step, on fields archived
//! and read back through the FDB on a simulated DAOS cluster.
//!
//! Run: `make artifacts && cargo run --release --example operational_run`

use std::rc::Rc;

use fdbr::bench::scenario::{deploy, RedundancyOpt, SystemKind};
use fdbr::hw::profiles::Testbed;
use fdbr::runtime::{PgenPipeline, PjrtRuntime};
use fdbr::workflow::driver::{run, OperationalConfig};

fn main() -> anyhow::Result<()> {
    let dep = deploy(Testbed::Gcp, SystemKind::Daos, 2, 4, RedundancyOpt::None);
    let runtime = PjrtRuntime::cpu()?;
    println!("PJRT platform: {}", runtime.platform());
    let pgen = Rc::new(PgenPipeline::new(&runtime, 8, 64)?);

    let cfg = OperationalConfig {
        members: 2,
        procs_per_member: 4,
        steps: 6,
        fields_per_proc_step: 8,
        grid: 64,
        real_compute: true,
    };
    let invocations = pgen.clone();
    let report = run(&dep, cfg, pgen);

    println!("== operational run (DAOS backends) ==");
    println!("  fields archived:        {}", report.fields_written);
    println!("  fields post-processed:  {}", report.fields_read);
    println!("  derived products:       {}", report.products);
    println!("  PJRT pgen invocations:  {}", invocations.invocations());
    println!("  simulated makespan:     {}", report.makespan);
    println!("  client time profile:    {}", report.trace.render());
    assert_eq!(report.fields_read, report.fields_written);
    assert!(report.products > 0);
    println!("PASSED: all layers compose (Pallas → JAX → HLO → PJRT → FDB → DES)");
    Ok(())
}
