//! fdb-hammer scalability sweep: the thesis' headline comparison
//! (Figs 4.12/4.21 shape) — DAOS vs Lustre vs Ceph as servers scale.
//!
//! Run: `cargo run --release --example hammer_sweep`

use fdbr::bench::hammer::{run, HammerConfig};
use fdbr::bench::scenario::{deploy, RedundancyOpt, SystemKind};
use fdbr::hw::profiles::Testbed;

fn main() {
    println!("fdb-hammer sweep on simulated GCP (2:1 clients:servers, 8 procs/node)");
    println!("{:<8} {:>8} {:>12} {:>12}", "system", "servers", "write GiB/s", "read GiB/s");
    for kind in [SystemKind::Lustre, SystemKind::Daos, SystemKind::Ceph] {
        for servers in [2usize, 4, 8] {
            let dep = deploy(Testbed::Gcp, kind, servers, servers * 2, RedundancyOpt::None);
            let (r, _) = run(
                &dep,
                HammerConfig {
                    procs_per_node: 8,
                    nsteps: 5,
                    nparams: 5,
                    nlevels: 4,
                    field_size: 1 << 20,
                    check: false,
                    contention: false,
                },
            );
            println!(
                "{:<8} {:>8} {:>12.2} {:>12.2}",
                kind.label(),
                servers,
                r.gibs_w(),
                r.gibs_r()
            );
        }
    }
}
