//! Same FDB workload through all four Store backends (POSIX/Lustre,
//! DAOS, Ceph/RADOS, S3), verifying the thesis' semantic differences:
//! POSIX needs flush() for visibility; object stores are visible
//! immediately; all are byte-exact.
//!
//! Run: `cargo run --release --example backend_comparison`

use std::rc::Rc;

use fdbr::bench::scenario::{deploy, RedundancyOpt, SystemKind};
use fdbr::fdb::schema::example_identifier;
use fdbr::fdb::{BackendConfig, Fdb, FdbBuilder};
use fdbr::hw::profiles::Testbed;
use fdbr::sim::exec::Sim;

fn exercise(mut w: Fdb, mut r: Fdb, sim: &Sim, label: &'static str) {
    sim.spawn(async move {
        let id = example_identifier();
        w.archive(&id, b"backend-comparison-payload").await.unwrap();
        w.flush().await.expect("flush");
        w.close().await.expect("close");
        let h = r.retrieve(&id).await.unwrap().expect("retrievable");
        let bytes = r.read(&h).await.unwrap().to_vec();
        assert_eq!(bytes, b"backend-comparison-payload");
        println!("  {label:<14} archive→flush→retrieve roundtrip OK");
    });
}

fn main() {
    println!("== backend comparison ==");
    for kind in [SystemKind::Lustre, SystemKind::Daos, SystemKind::Ceph] {
        let dep = deploy(Testbed::Gcp, kind, 2, 2, RedundancyOpt::None);
        let nodes = dep.client_nodes();
        // the same declarative construction path for every backend
        let (w, r) = (dep.fdb(&nodes[0]), dep.fdb(&nodes[1]));
        exercise(w, r, &dep.sim, kind.label());
        dep.sim.run();
    }
    // S3 store (process-local catalogue — thesis §3.3)
    let dep = deploy(Testbed::Gcp, SystemKind::Lustre, 1, 2, RedundancyOpt::None);
    let server = dep.cluster.storage_nodes().next().unwrap().clone();
    let cnode = dep.client_nodes()[0].clone();
    let s3 = Rc::new(fdbr::s3::MemS3::new(&dep.sim, &server, &cnode));
    let mut fdb = FdbBuilder::new(&dep.sim)
        .backend(BackendConfig::S3 {
            s3: s3.clone(),
            client_tag: "p0".to_string(),
            multipart: false,
        })
        .build()
        .expect("valid config");
    dep.sim.spawn(async move {
        let id = example_identifier();
        fdb.archive(&id, b"s3-payload").await.unwrap();
        let h = fdb.retrieve(&id).await.unwrap().unwrap();
        assert_eq!(fdb.read(&h).await.unwrap().to_vec(), b"s3-payload");
        println!("  {:<14} archive→retrieve roundtrip OK (PutObject durable on archive)", "S3");
    });
    dep.sim.run();
    println!("all backends PASSED");
}
