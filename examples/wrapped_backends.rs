//! Composable backend wrappers, recursively stacked: a sharded
//! catalogue over a tiered store whose fast front tier is a POSIX
//! burst buffer and whose durable back tier is a 2-way replicated
//! POSIX store — one declarative `BackendConfig` tree.
//!
//! Run: `cargo run --release --example wrapped_backends`

use std::rc::Rc;

use fdbr::bench::scenario::{deploy, RedundancyOpt, SystemKind, SystemUnderTest, WrapperOpt};
use fdbr::fdb::schema::example_identifier;
use fdbr::fdb::{BackendConfig, FdbBuilder};
use fdbr::hw::profiles::Testbed;

fn main() {
    println!("== composable backend wrappers ==");

    // --- the one-knob path: sweep wrappers over a deployment
    for wrapper in [
        WrapperOpt::Bare,
        WrapperOpt::Tiered,
        WrapperOpt::Replicated(2),
        WrapperOpt::Sharded(4),
    ] {
        let dep = deploy(Testbed::Gcp, SystemKind::Lustre, 2, 2, RedundancyOpt::None)
            .with_wrapper(wrapper);
        let config = dep.backend_config();
        let nodes = dep.client_nodes();
        let mut w = dep.fdb(&nodes[0]);
        let mut r = dep.fdb(&nodes[1]);
        dep.sim.spawn(async move {
            let id = example_identifier();
            w.archive(&id, b"wrapped-payload").await.unwrap();
            w.flush().await.unwrap();
            w.close().await.expect("close");
            let h = r.retrieve(&id).await.unwrap().expect("retrievable");
            assert_eq!(r.read(&h).await.unwrap().to_vec(), b"wrapped-payload");
        });
        dep.sim.run();
        println!("  {:<32} roundtrip OK", config.describe());
    }

    // --- the fully explicit path: a recursive config tree
    let dep = deploy(Testbed::Gcp, SystemKind::Lustre, 2, 2, RedundancyOpt::None);
    let SystemUnderTest::Lustre(fs) = &dep.system else {
        unreachable!()
    };
    let config = BackendConfig::Sharded {
        inner: Box::new(BackendConfig::Tiered {
            front: Box::new(BackendConfig::Posix {
                fs: fs.clone(),
                root: "/scm".to_string(),
            }),
            back: Box::new(BackendConfig::Replicated {
                inner: Box::new(BackendConfig::Posix {
                    fs: fs.clone(),
                    root: "/fdb".to_string(),
                }),
                copies: 2,
            }),
        }),
        shards: 2,
    };
    println!("  explicit tree: {}", config.describe());
    let nodes = dep.client_nodes();
    let mk = |node: &Rc<fdbr::hw::node::Node>| {
        FdbBuilder::new(&dep.sim)
            .node(node)
            .backend(config.clone())
            .build()
            .expect("valid recursive config")
    };
    let mut w = mk(&nodes[0]);
    let mut r = mk(&nodes[1]);
    dep.sim.spawn(async move {
        for step in 1..=4u32 {
            let id = example_identifier().with("step", step.to_string());
            w.archive(&id, format!("field-{step}").as_bytes()).await.unwrap();
        }
        // flush writes the absorbed fields through to both replicas of
        // the back tier, then publishes the sharded index
        w.flush().await.unwrap();
        w.close().await.expect("close");
        for step in 1..=4u32 {
            let id = example_identifier().with("step", step.to_string());
            let h = r.retrieve(&id).await.unwrap().expect("retrievable");
            assert_eq!(
                r.read(&h).await.unwrap().to_vec(),
                format!("field-{step}").into_bytes()
            );
        }
    });
    dep.sim.run();
    println!("  sharded(tiered(posix,replicated(posix))) roundtrip OK");
    println!("all wrapped backends PASSED");
}
