//! Typed offline stub of the PJRT/XLA client surface `fdbr::runtime`
//! programs against. The real backend (an XLA build with PJRT CPU
//! support) is not available in the offline container, so client
//! construction fails with a clear error; every call site gates on it
//! (`PjrtRuntime::cpu()?`) or on artifact presence, and the integration
//! tests skip cleanly. The API shapes mirror the real bindings so the
//! runtime module compiles unchanged when the real crate is swapped in.

use std::fmt;

#[derive(Debug)]
pub struct Error(String);

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.0)
    }
}

impl std::error::Error for Error {}

pub type Result<T> = std::result::Result<T, Error>;

fn unavailable(what: &str) -> Error {
    Error(format!(
        "{what}: XLA/PJRT backend unavailable in this offline build \
         (vendor/xla is a stub — link a real xla crate to execute artifacts)"
    ))
}

/// PJRT client handle (stub: construction always fails).
pub struct PjRtClient {
    _private: (),
}

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient> {
        Err(unavailable("PjRtClient::cpu"))
    }

    pub fn platform_name(&self) -> String {
        "stub".to_string()
    }

    pub fn compile(&self, _comp: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        Err(unavailable("PjRtClient::compile"))
    }
}

/// Parsed HLO module (stub).
pub struct HloModuleProto {
    _private: (),
}

impl HloModuleProto {
    pub fn from_text_file(_path: &str) -> Result<HloModuleProto> {
        Err(unavailable("HloModuleProto::from_text_file"))
    }
}

/// An XLA computation built from an HLO proto (stub).
pub struct XlaComputation {
    _private: (),
}

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation { _private: () }
    }
}

/// A compiled executable (stub: unreachable without a real client).
pub struct PjRtLoadedExecutable {
    _private: (),
}

impl PjRtLoadedExecutable {
    pub fn execute<T>(&self, _inputs: &[T]) -> Result<Vec<Vec<PjRtBuffer>>> {
        Err(unavailable("PjRtLoadedExecutable::execute"))
    }
}

/// A device buffer returned by execution (stub).
pub struct PjRtBuffer {
    _private: (),
}

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        Err(unavailable("PjRtBuffer::to_literal_sync"))
    }
}

/// A host literal (stub: shape bookkeeping only).
pub struct Literal {
    _private: (),
}

impl Literal {
    pub fn scalar(_v: f32) -> Literal {
        Literal { _private: () }
    }

    pub fn vec1(_data: &[f32]) -> Literal {
        Literal { _private: () }
    }

    pub fn reshape(&self, _dims: &[i64]) -> Result<Literal> {
        Ok(Literal { _private: () })
    }

    pub fn to_tuple1(&self) -> Result<Literal> {
        Err(unavailable("Literal::to_tuple1"))
    }

    pub fn to_vec<T>(&self) -> Result<Vec<T>> {
        Err(unavailable("Literal::to_vec"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn client_construction_fails_with_clear_error() {
        let err = PjRtClient::cpu().unwrap_err();
        assert!(err.to_string().contains("offline"), "{err}");
    }
}
