//! Vendored offline subset of the `anyhow` API: `Error`, `Result`,
//! `Context`, `anyhow!`, `bail!`. Enough surface for this crate's
//! coordinator/runtime error paths; no backtraces, no `Send` bound
//! (the simulator is single-threaded).

use std::fmt;

/// A context-carrying error: a message plus an optional boxed cause.
pub struct Error {
    msg: String,
    source: Option<Box<dyn std::error::Error + 'static>>,
}

impl Error {
    pub fn msg(msg: impl fmt::Display) -> Error {
        Error {
            msg: msg.to_string(),
            source: None,
        }
    }

    /// Wrap an existing error under a new context message.
    pub fn context(self, msg: impl fmt::Display) -> Error {
        Error {
            msg: msg.to_string(),
            source: Some(Box::new(Wrapped(self))),
        }
    }

    fn chain_string(&self) -> String {
        let mut out = self.msg.clone();
        let mut src: Option<&(dyn std::error::Error + 'static)> = self.source.as_deref();
        while let Some(e) = src {
            out.push_str(": ");
            out.push_str(&e.to_string());
            src = e.source();
        }
        out
    }
}

/// Adapter so an [`Error`] can sit in the `source` chain (anyhow::Error
/// itself intentionally does not implement `std::error::Error`).
struct Wrapped(Error);

impl fmt::Display for Wrapped {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.0.msg)
    }
}

impl fmt::Debug for Wrapped {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.0.msg)
    }
}

impl std::error::Error for Wrapped {
    fn source(&self) -> Option<&(dyn std::error::Error + 'static)> {
        self.0.source.as_deref()
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        if f.alternate() {
            // `{:#}` renders the whole context chain, like real anyhow
            write!(f, "{}", self.chain_string())
        } else {
            write!(f, "{}", self.msg)
        }
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.msg)?;
        let mut src: Option<&(dyn std::error::Error + 'static)> = self.source.as_deref();
        if src.is_some() {
            write!(f, "\n\nCaused by:")?;
        }
        while let Some(e) = src {
            write!(f, "\n    {e}")?;
            src = e.source();
        }
        Ok(())
    }
}

impl<E: std::error::Error + 'static> From<E> for Error {
    fn from(e: E) -> Error {
        Error {
            msg: e.to_string(),
            source: e.source().map(|_| {
                // keep only the rendered chain: sources of borrowed
                // errors can't be moved, so flatten them into the message
                Box::new(Flat(flatten_sources(&e))) as Box<dyn std::error::Error>
            }),
        }
    }
}

fn flatten_sources(e: &dyn std::error::Error) -> String {
    let mut parts = Vec::new();
    let mut src = e.source();
    while let Some(s) = src {
        parts.push(s.to_string());
        src = s.source();
    }
    parts.join(": ")
}

struct Flat(String);

impl fmt::Display for Flat {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.0)
    }
}

impl fmt::Debug for Flat {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.0)
    }
}

impl std::error::Error for Flat {}

pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Extension trait adding `.context(..)` / `.with_context(..)`.
pub trait Context<T> {
    fn context<C: fmt::Display>(self, ctx: C) -> Result<T, Error>;
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T, Error>;
}

impl<T, E: std::error::Error + 'static> Context<T> for std::result::Result<T, E> {
    fn context<C: fmt::Display>(self, ctx: C) -> Result<T, Error> {
        self.map_err(|e| Error::from(e).context(ctx))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T, Error> {
        self.map_err(|e| Error::from(e).context(f()))
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: fmt::Display>(self, ctx: C) -> Result<T, Error> {
        self.ok_or_else(|| Error::msg(ctx))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T, Error> {
        self.ok_or_else(|| Error::msg(f()))
    }
}

#[macro_export]
macro_rules! anyhow {
    ($($arg:tt)*) => {
        $crate::Error::msg(format!($($arg)*))
    };
}

#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return Err($crate::anyhow!($($arg)*))
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_err() -> std::io::Error {
        std::io::Error::new(std::io::ErrorKind::NotFound, "gone")
    }

    #[test]
    fn context_chain_renders_alternate() {
        let r: Result<(), std::io::Error> = Err(io_err());
        let e = r.context("opening artifact").unwrap_err();
        assert_eq!(format!("{e}"), "opening artifact");
        assert_eq!(format!("{e:#}"), "opening artifact: gone");
    }

    #[test]
    fn option_context() {
        let v: Option<u32> = None;
        let e = v.context("missing").unwrap_err();
        assert_eq!(format!("{e}"), "missing");
        assert_eq!(Some(7u32).context("missing").unwrap(), 7);
    }

    #[test]
    fn bail_macro_returns_err() {
        fn f(x: bool) -> Result<u32> {
            if x {
                bail!("bad flag {x}");
            }
            Ok(1)
        }
        assert!(f(true).is_err());
        assert_eq!(f(false).unwrap(), 1);
    }
}
